//! The serving engine: continuous batching over a pluggable execution
//! backend.
//!
//! One `Engine` owns a backend (sim or PJRT — see [`crate::runtime`]), the
//! paged quantized KV pool, the scheduler, and all in-flight sequence
//! state. Each `step()` runs exactly one iteration — a prefill chunk or a
//! decode batch — mirroring iteration-level scheduling (Orca) with chunked
//! prefill (Sarathi) and paged KV (vLLM), the serving substrate the paper's
//! §5 evaluation assumes.
//!
//! Dataflow per decode step:
//!   gather quantized KV from the pool → padded `[L,B,Hkv,T,·]` buffers →
//!   backend decode (the attention path dequantizes on the fly) → sample
//!   logits → append the backend-emitted quantized KV codes for the new
//!   token back into the pool (no engine-side re-quantization).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::preempt::{LadderCost, PreemptMechanism, VictimCost, HBM_BANDWIDTH_BPS};
use super::request::{FinishReason, Phase, Request, RequestOutput, SeqState};
use super::sampler::Sampler;
use super::scheduler::{Action, Scheduler};
use crate::config::{layer_importance, BackendKind, EngineConfig, LadderPolicy, PreemptionMode};
use crate::kvcache::prefix::chain_keys_under;
use crate::kvcache::swap::{disk_transfer_time_s, snapshot_bytes, transfer_time_s};
use crate::kvcache::{
    KvLayout, KvPool, PagedSwapStore, PrefixCache, SeqHandle, SwapBackend, SwapStore,
};
use crate::metrics::{PreemptionSummary, PrefixCacheSummary, TelemetrySummary};
use crate::store::{fetch_chain, resolve_shared_prefix, PageFileStore, StoreReceipt};
use crate::runtime::{
    DecodeArgs, ExecutionBackend, ModelSpec, PrefillArgs, SimBackend, StepOutputs,
};
use crate::trace::{EventKind, TraceDump, TraceEvent, TraceRecorder, NO_ID};

/// What one engine iteration did.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub action: Action,
    /// (request id, token) pairs emitted this step.
    pub emitted: Vec<(u64, i32)>,
    /// Requests that finished this step.
    pub finished: Vec<u64>,
}

/// Aggregate engine counters.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub prefill_iters: usize,
    pub decode_iters: usize,
    pub idle_iters: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    /// Decode-batch slots wasted on padding (fixed compiled batch sizes).
    pub padded_slots: usize,
    pub aborted: usize,
    /// Prompt tokens served from the prefix cache instead of prefilling.
    pub prefill_tokens_skipped: usize,
    /// Iterations that preempted a victim (each also ran the decode the
    /// preemption unblocked).
    pub preempt_iters: usize,
    /// Iterations spent restoring a swapped-out sequence from the host
    /// store (no prefill runs in these; they are not `prefill_iters`).
    pub swap_in_iters: usize,
    /// Modeled HBM read bytes of every KV gather executed (the per-step
    /// [`GatherPlan::hbm_bytes`](crate::kvcache::pool::GatherPlan) sums) —
    /// the memory-traffic side of the decode hot path.
    pub gather_hbm_bytes: usize,
    /// `gather_hbm_bytes` split per [`KvPrecision`](crate::kvcache::KvPrecision)
    /// ladder rung (index = `ladder_rank()`: kv16/kv8/kv4). The three
    /// buckets always sum exactly to `gather_hbm_bytes`.
    pub gather_hbm_bytes_by_rung: [usize; 3],
    /// Ladder transcode read+write HBM bytes, attributed to each changed
    /// layer's *destination* rung. Sums to `PreemptStats::ladder_transcoded_bytes`.
    pub transcode_bytes_by_rung: [usize; 3],
    /// Swap-preemption PCIe bytes (out + in, codes + scales), split per
    /// rung of the layout the snapshot was exported at.
    pub swap_pcie_bytes_by_rung: [usize; 3],
    /// Cross-replica migration PCIe bytes (snapshot export + import,
    /// codes + scales), split per rung of the snapshot's recorded layout.
    /// Deliberately separate from `swap_pcie_bytes_by_rung` so the
    /// swap-event ↔ counter reconciliation stays exact under
    /// disaggregated serving.
    pub migrate_pcie_bytes_by_rung: [usize; 3],
    /// Iterations spent importing a migrated snapshot (not `prefill_iters`,
    /// not `swap_in_iters`).
    pub migrate_in_iters: usize,
    /// Page-file-store traffic (swap-outs/ins through the paged backend,
    /// prefix publishes, and shared-prefix fetches), split per rung of
    /// each payload's recorded layout. Reconciles exactly with the sum of
    /// `StoreWrite`/`StoreRead` trace event bytes.
    pub store_disk_bytes_by_rung: [usize; 3],
    /// Admissions served from the host-global prefix store (as opposed to
    /// this replica's own in-pool index).
    pub store_prefix_hits: usize,
    /// Prompt tokens adopted from the host-global prefix store.
    pub store_prefix_hit_tokens: usize,
    /// Full prefix blocks this engine published into the shared store.
    pub store_published_blocks: usize,
    /// Modeled device time accumulated by the backend (sim backend only;
    /// the PJRT path is wall-clock-timed by callers instead), plus modeled
    /// PCIe time for swap-preemption transfers.
    pub sim_time_s: f64,
}

/// Preemption-decision counters (swap *transfer* counters live in
/// [`SwapStore::stats`]; [`Engine::preemption_summary`] merges both).
#[derive(Debug, Default, Clone, Copy)]
pub struct PreemptStats {
    /// Victims preempted (any mechanism).
    pub preemptions: usize,
    /// Victims preserved by swap-out.
    pub swap_preemptions: usize,
    /// Victims released for recompute (includes swap-ins downgraded when
    /// the pool could not take the restore).
    pub recompute_preemptions: usize,
    /// Tokens queued for re-prefill by recompute preemptions (prefix-cache
    /// hits at resume may serve part of them without running).
    pub recomputed_tokens: usize,
    /// Victims preserved by a pool-wide precision-ladder rung: sequences
    /// that had started generating and were restarted at the narrower
    /// layout (the per-mechanism buckets sum to `preemptions`:
    /// swap + recompute + ladder).
    pub ladder_preemptions: usize,
    /// Pool-wide ladder rungs taken (each transcodes every resident block).
    pub ladder_events: usize,
    /// Modeled HBM read+write traffic of all ladder transcodes, bytes.
    pub ladder_transcoded_bytes: usize,
    /// Pool capacity gained by laddering: newly affordable blocks at the
    /// post-rung layout, in bytes.
    pub ladder_freed_bytes: usize,
    /// Generated tokens dropped by ladder restarts (regenerated at the
    /// final layout — the determinism contract's re-decode cost).
    pub ladder_dropped_tokens: usize,
    /// Sequences lost to pool exhaustion (abort mode, or a sole runner no
    /// preemption could save).
    pub oom_aborts: usize,
}

/// Cross-replica KV-migration counters (disaggregated prefill/decode and
/// replica drain — DESIGN.md §13). Kept apart from [`PreemptStats`] and
/// [`SwapStats`](crate::kvcache::SwapStats): migration is a *placement*
/// mechanism, not a preemption, so the invariant
/// `preemptions == swap + recompute + ladder` must hold across any number
/// of migrations.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct MigrationStats {
    /// Snapshots exported for another replica (finish-time handoff or
    /// drain).
    pub migrated_out: usize,
    /// Snapshots imported into this replica's pool.
    pub migrated_in: usize,
    /// Migrated arrivals whose import could not fit even after eviction;
    /// they fell back to a full re-prefill (still bit-identical output,
    /// just paid in compute instead of PCIe).
    pub migrate_in_downgrades: usize,
    /// Total snapshot bytes (codes + scales) shipped out.
    pub migrated_out_bytes: usize,
    /// Total snapshot bytes (codes + scales) imported.
    pub migrated_in_bytes: usize,
}

/// Everything needed to resume one in-flight request on another replica:
/// the original request, the tokens already generated, and (when the
/// sequence had live KV) its layout-tagged snapshot. Produced by
/// [`Engine::drain_resumables`] and consumed by [`Engine::submit_migrated`].
#[derive(Debug, Clone)]
pub struct ResumeArtifact {
    /// The request's id on the *source* replica (ids are per-engine; the
    /// destination assigns a fresh one).
    pub source_id: u64,
    pub request: Request,
    /// Tokens generated before the drain (empty when it never decoded).
    pub generated: Vec<i32>,
    /// Live KV at the source's layout, or `None` when the sequence held
    /// none (still queued, or mid-prefill — re-prefill is then cheaper
    /// than shipping a partial cache).
    pub snapshot: Option<crate::kvcache::SeqSnapshot>,
}

/// The engine.
pub struct Engine {
    backend: Box<dyn ExecutionBackend>,
    model: ModelSpec,
    pool: KvPool,
    /// Prefix-sharing index over `pool` (None when disabled in config).
    prefix: Option<PrefixCache>,
    /// Host-side tier for swap-preempted sequences' KV (DESIGN.md §8):
    /// in-memory by default, page-file-backed when `cfg.store` is set.
    swap: Box<dyn SwapBackend>,
    /// The shared page-file store, when configured (DESIGN.md §14).
    store: Option<Arc<PageFileStore>>,
    /// This pool layout's registered root key in `store` (re-registered on
    /// every ladder rung, since the rung re-keys the chain space).
    store_root: Option<u64>,
    pub preempt_stats: PreemptStats,
    /// Cross-replica migration counters (DESIGN.md §13).
    pub migration_stats: MigrationStats,
    /// Snapshots exported at finish for `export_on_finish` sequences,
    /// awaiting pickup by the disaggregation orchestrator.
    migration_exports: Vec<(u64, crate::kvcache::SeqSnapshot)>,
    cfg: EngineConfig,
    scheduler: Scheduler,
    sampler: Sampler,
    rng: crate::util::rng::Rng,
    seqs: BTreeMap<u64, SeqState>,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    next_id: u64,
    outputs: Vec<RequestOutput>,
    pub stats: EngineStats,
    /// Flight recorder (DESIGN.md §12). `None` unless `cfg.trace` — the
    /// hot path then pays exactly one branch per would-be event.
    trace: Option<Arc<TraceRecorder>>,
}

#[cfg(feature = "pjrt")]
fn pjrt_backend(cfg: &EngineConfig) -> Result<Box<dyn ExecutionBackend>> {
    Ok(Box::new(crate::runtime::PjrtBackend::new(
        &cfg.artifacts_dir,
        cfg.precision,
        cfg.max_batch,
    )?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(_cfg: &EngineConfig) -> Result<Box<dyn ExecutionBackend>> {
    bail!("this build has no PJRT support (rebuild with `--features pjrt`), use backend `sim`")
}

impl Engine {
    /// Construct an engine for `cfg`, building the backend `cfg.backend`
    /// names: the hermetic sim backend (default) or the PJRT artifact
    /// runtime.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let backend: Box<dyn ExecutionBackend> = match cfg.backend {
            BackendKind::Sim => Box::new(SimBackend::with_device(
                ModelSpec::tiny(),
                cfg.precision,
                cfg.seed,
                cfg.max_batch,
                crate::config::DeviceProfile::by_name(&cfg.device)
                    .ok_or_else(|| anyhow!("unknown device profile `{}`", cfg.device))?,
                cfg.tp,
            )?),
            BackendKind::Pjrt => pjrt_backend(&cfg)?,
        };
        Self::with_backend(cfg, backend)
    }

    /// Construct an engine around an already-built backend (tests, custom
    /// deployments).
    pub fn with_backend(cfg: EngineConfig, backend: Box<dyn ExecutionBackend>) -> Result<Self> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        if backend.precision() != cfg.precision {
            bail!(
                "backend precision {} != configured {}",
                backend.precision(),
                cfg.precision
            );
        }
        let m = backend.model().clone();
        let plan = backend.plan();
        if !plan.decode_batches.iter().any(|&b| b >= 1) {
            bail!("backend plan has no decode batch buckets");
        }
        if plan.prefill_chunks.is_empty() {
            bail!("backend plan has no prefill chunks");
        }
        let layout = match cfg.kv_layout.as_deref() {
            Some(spec) => KvLayout::parse(spec, m.n_layers)?,
            None => KvLayout::from_dtype(cfg.precision.kv, m.n_layers)?,
        };
        let pool = KvPool::with_layout(
            layout.clone(),
            m.n_kv_heads,
            m.head_dim,
            cfg.kv_block_tokens,
            cfg.kv_pool_tokens,
        )?;
        // The index is keyed by the pool's full per-layer layout, so an
        // engine's cached blocks can never satisfy a lookup at any other
        // precision assignment (and every ladder rung re-keys the root).
        let prefix = cfg
            .enable_prefix_cache
            .then(|| PrefixCache::with_layout(layout, cfg.kv_block_tokens, cfg.prefix_cache_blocks));
        let sampler = Sampler { temperature: cfg.temperature, top_k: cfg.top_k };
        let rng = crate::util::rng::Rng::new(cfg.seed);
        let pool_layout = pool.layout().clone();
        let store = cfg.store.clone();
        let (swap, store_root): (Box<dyn SwapBackend>, Option<u64>) = match &store {
            Some(st) => {
                // Register this pool's chain-key space so other replicas
                // (and post-restart processes) can resolve the blocks this
                // engine publishes.
                let root = st.register_layout(&pool_layout, cfg.kv_block_tokens)?;
                // Upper-bound wire bytes/token for capacity probes: the
                // ladder only narrows, so the admission layout bounds every
                // later snapshot.
                let hint = pool_layout.token_code_bytes(m.n_kv_heads, m.head_dim)
                    + pool_layout.n_layers() * 2 * m.n_kv_heads * 4;
                let paged = PagedSwapStore::new(
                    st.clone(),
                    cfg.kv_block_tokens,
                    cfg.swap_budget_blocks,
                    hint,
                );
                (Box::new(paged) as Box<dyn SwapBackend>, Some(root))
            }
            None => (
                Box::new(SwapStore::new(cfg.kv_block_tokens, cfg.swap_budget_blocks))
                    as Box<dyn SwapBackend>,
                None,
            ),
        };
        let trace = cfg
            .trace
            .then(|| Arc::new(TraceRecorder::with_capacity(cfg.trace_ring_capacity)));
        Ok(Self {
            backend,
            model: m,
            pool,
            prefix,
            swap,
            store,
            store_root,
            preempt_stats: PreemptStats::default(),
            migration_stats: MigrationStats::default(),
            migration_exports: Vec::new(),
            scheduler: Scheduler::new(cfg.scheduler),
            sampler,
            rng,
            cfg,
            seqs: BTreeMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            next_id: 0,
            outputs: Vec::new(),
            stats: EngineStats::default(),
            trace,
        })
    }

    /// Prepare the backend for serving (PJRT: compile every reachable
    /// graph; sim: no-op).
    pub fn warmup(&self) -> Result<()> {
        self.backend.warmup()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn model(&self) -> &ModelSpec {
        &self.model
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Submit a request; returns its id.
    ///
    /// Malformed requests (empty prompt, out-of-vocab tokens, longer than
    /// the model context) are rejected with an error. A *valid* request
    /// whose prompt + generation budget can never fit the KV pool is
    /// accepted and immediately finished with [`FinishReason::Aborted`] —
    /// queueing it would stall the scheduler forever (see
    /// `scheduler::next_action`), and erroring would make pool sizing a
    /// protocol-visible failure mode.
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        let total = req.prompt.len() + req.max_new_tokens;
        let m = &self.model;
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if total > m.max_seq_len {
            bail!("request needs {total} tokens > context {}", m.max_seq_len);
        }
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= m.vocab_size) {
            bail!("prompt token {t} outside vocab {}", m.vocab_size);
        }
        let id = self.next_id;
        self.next_id += 1;
        let oversized = self.pool.blocks_for(total) > self.pool.total_blocks();
        let mut seq = SeqState::new(id, req, Instant::now());
        seq.submitted_sim_s = self.stats.sim_time_s;
        self.emit(
            self.stats.sim_time_s,
            EventKind::Admit {
                id,
                prompt_len: seq.prompt.len() as u64,
                max_new_tokens: seq.max_new_tokens as u64,
            },
        );
        self.seqs.insert(id, seq);
        if oversized {
            // Reject at submit time instead of idling forever: the
            // conservative admission reservation (prompt + generation) can
            // never be satisfied, even by an empty pool.
            self.seqs.get_mut(&id).unwrap().abort_reason = Some(format!(
                "request needs {} KV blocks but the pool holds {}",
                self.pool.blocks_for(total),
                self.pool.total_blocks()
            ));
            self.finish(id, FinishReason::Aborted);
            self.stats.aborted += 1;
        } else {
            self.waiting.push_back(id);
        }
        Ok(id)
    }

    /// Submit a request to this engine as the *prefill tier* of a
    /// disaggregated deployment (DESIGN.md §13): run the prompt, sample
    /// exactly the first token, then export the sequence's KV as a
    /// layout-tagged snapshot at finish. The snapshot (picked up via
    /// [`Engine::take_migration_exports`]) plus the first token are what a
    /// decode replica needs to continue the generation bit-identically.
    pub fn submit_prefill_only(&mut self, mut req: Request) -> Result<u64> {
        req.max_new_tokens = 1;
        let id = self.submit(req)?;
        // An oversized request already finished (Aborted) inside `submit`
        // and has no state left — nothing to export for it.
        if let Some(s) = self.seqs.get_mut(&id) {
            s.export_on_finish = true;
        }
        Ok(id)
    }

    /// Submit a request migrated from another replica: the original
    /// request, the tokens it has generated so far, and (usually) its KV
    /// snapshot — already transcoded to *this* pool's layout. With a
    /// snapshot the sequence skips prefill entirely and enters decode on
    /// import; without one (downgraded or drained mid-prefill) it
    /// re-prefills its resident stream, which is slower but produces the
    /// same tokens. Returns the engine-local id (ids never migrate).
    pub fn submit_migrated(
        &mut self,
        req: Request,
        generated: Vec<i32>,
        snapshot: Option<crate::kvcache::SeqSnapshot>,
    ) -> Result<u64> {
        let total = req.prompt.len() + req.max_new_tokens;
        let m = &self.model;
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if total > m.max_seq_len {
            bail!("request needs {total} tokens > context {}", m.max_seq_len);
        }
        if let Some(&t) = req
            .prompt
            .iter()
            .chain(generated.iter())
            .find(|&&t| t < 0 || t as usize >= m.vocab_size)
        {
            bail!("token {t} outside vocab {}", m.vocab_size);
        }
        if !generated.is_empty() {
            if generated.len() >= req.max_new_tokens {
                bail!("migrated request has nothing left to decode");
            }
            if req.stop_token.is_some_and(|stop| *generated.last().unwrap() == stop) {
                bail!("migrated request already sampled its stop token");
            }
        }
        if let Some(snap) = &snapshot {
            if generated.is_empty() {
                bail!("a migrated snapshot implies a sampled first token, but none was shipped");
            }
            // The cache must hold exactly prompt ++ generated[..g-1]: the
            // last generated token is the pending decode input.
            let expect = req.prompt.len() + generated.len() - 1;
            if snap.len != expect {
                bail!(
                    "migrated snapshot holds {} tokens, expected {expect} \
                     (prompt {} + generated {} - 1)",
                    snap.len,
                    req.prompt.len(),
                    generated.len()
                );
            }
            if snap.kv_heads != m.n_kv_heads || snap.head_dim != m.head_dim {
                bail!(
                    "migrated snapshot geometry Hkv={} hd={} does not match this model \
                     (Hkv={} hd={})",
                    snap.kv_heads,
                    snap.head_dim,
                    m.n_kv_heads,
                    m.head_dim
                );
            }
            // Reject eagerly with the routing-level message; `import_seq`
            // would also catch this, but only after admission.
            if snap.fingerprint() != self.pool.layout().fingerprint() {
                bail!(
                    "migrated snapshot layout `{}` does not match this replica's `{}` — \
                     transcode before shipping",
                    snap.layout,
                    self.pool.layout()
                );
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let oversized = self.pool.blocks_for(total) > self.pool.total_blocks();
        let mut seq = SeqState::new(id, req, Instant::now());
        seq.submitted_sim_s = self.stats.sim_time_s;
        seq.generated = generated;
        seq.rebuild_seq_tokens();
        seq.migrate_snapshot = snapshot;
        self.emit(
            self.stats.sim_time_s,
            EventKind::Admit {
                id,
                prompt_len: seq.prompt.len() as u64,
                max_new_tokens: seq.max_new_tokens as u64,
            },
        );
        self.seqs.insert(id, seq);
        if oversized {
            self.seqs.get_mut(&id).unwrap().abort_reason = Some(format!(
                "request needs {} KV blocks but the pool holds {}",
                self.pool.blocks_for(total),
                self.pool.total_blocks()
            ));
            self.finish(id, FinishReason::Aborted);
            self.stats.aborted += 1;
        } else {
            self.waiting.push_back(id);
        }
        Ok(id)
    }

    /// Drain snapshots exported at finish by
    /// [`Engine::submit_prefill_only`] sequences, keyed by engine-local id.
    pub fn take_migration_exports(&mut self) -> Vec<(u64, crate::kvcache::SeqSnapshot)> {
        std::mem::take(&mut self.migration_exports)
    }

    /// Drain this replica for retirement: stop serving and turn every
    /// in-flight request — running, queued, swapped-out, or
    /// pending-import — into a [`ResumeArtifact`] another replica can
    /// resume via [`Engine::submit_migrated`]. Decoding sequences (live,
    /// swapped, or pending-import) ship their KV; queued and mid-prefill
    /// sequences ship none (re-prefill at the destination restarts them
    /// bit-identically and is cheaper than shipping a partial cache).
    /// Preemption and swap counters are untouched: a drain is placement,
    /// not pressure.
    pub fn drain_resumables(&mut self) -> Result<Vec<ResumeArtifact>> {
        let ids: Vec<u64> =
            self.running.drain(..).chain(self.waiting.drain(..)).collect();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let mut s = self.seqs.remove(&id).expect("queued id has state");
            let snapshot = if let Some(h) = s.handle.take() {
                let snap = (s.phase == Phase::Decoding)
                    .then(|| self.pool.export_seq(h))
                    .transpose()?;
                self.pool.free_seq(h);
                snap
            } else if s.swapped {
                s.swapped = false;
                // `evacuate`, not `take`: leaving the store for another
                // replica is not a swap-in.
                self.swap.evacuate(id)?
            } else {
                s.migrate_snapshot.take()
            };
            if let Some(snap) = &snapshot {
                let by_rung = snap.bytes_by_rung();
                for (acc, b) in self.stats.migrate_pcie_bytes_by_rung.iter_mut().zip(by_rung) {
                    *acc += b;
                }
                let bytes = snapshot_bytes(snap);
                let dt = transfer_time_s(bytes);
                self.emit(
                    self.stats.sim_time_s,
                    EventKind::MigrateOut {
                        id,
                        bytes_by_rung: by_rung.map(|b| b as u64),
                        dur_s: dt,
                    },
                );
                self.stats.sim_time_s += dt;
                self.migration_stats.migrated_out += 1;
                self.migration_stats.migrated_out_bytes += bytes;
            }
            out.push(ResumeArtifact {
                source_id: id,
                request: Request {
                    prompt: s.prompt.clone(),
                    max_new_tokens: s.max_new_tokens,
                    stop_token: s.stop_token,
                },
                generated: s.generated.clone(),
                snapshot,
            });
        }
        Ok(out)
    }

    /// Whether any work remains.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain finished outputs.
    pub fn take_outputs(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.outputs)
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// Fraction of pool blocks currently in use (shared blocks count once).
    pub fn pool_utilization(&self) -> f64 {
        self.pool.used_blocks() as f64 / self.pool.total_blocks() as f64
    }

    /// Prefix-cache effectiveness counters (None when the cache is off).
    pub fn prefix_cache_summary(&self) -> Option<PrefixCacheSummary> {
        self.prefix.as_ref().map(|pc| PrefixCacheSummary::from(pc.stats))
    }

    /// Blocks currently pinned by the prefix cache.
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map(PrefixCache::cached_blocks).unwrap_or(0)
    }

    /// The host-side swap backend (budget/occupancy for the stats probe).
    pub fn swap_store(&self) -> &dyn SwapBackend {
        self.swap.as_ref()
    }

    /// The shared page-file store, when this engine was configured with one.
    pub fn store(&self) -> Option<&Arc<PageFileStore>> {
        self.store.as_ref()
    }

    /// Preemption effectiveness counters (decisions + swap traffic).
    pub fn preemption_summary(&self) -> PreemptionSummary {
        PreemptionSummary::new(self.preempt_stats, self.swap.stats())
    }

    /// The flight recorder, when tracing is enabled (`cfg.trace`).
    pub fn trace_recorder(&self) -> Option<&Arc<TraceRecorder>> {
        self.trace.as_ref()
    }

    /// Snapshot the whole resident trace ring (empty dump when off).
    pub fn trace_dump(&self) -> TraceDump {
        self.trace.as_ref().map(|t| t.dump()).unwrap_or_default()
    }

    /// Snapshot the newest `last` ring events (empty dump when off).
    pub fn trace_dump_last(&self, last: usize) -> TraceDump {
        self.trace.as_ref().map(|t| t.dump_last(last)).unwrap_or_default()
    }

    /// Precision-attributed byte telemetry + current per-layer occupancy.
    pub fn telemetry(&self) -> TelemetrySummary {
        TelemetrySummary {
            gather_hbm_bytes_by_rung: self.stats.gather_hbm_bytes_by_rung,
            transcode_bytes_by_rung: self.stats.transcode_bytes_by_rung,
            swap_pcie_bytes_by_rung: self.stats.swap_pcie_bytes_by_rung,
            migrate_pcie_bytes_by_rung: self.stats.migrate_pcie_bytes_by_rung,
            store_disk_bytes_by_rung: self.stats.store_disk_bytes_by_rung,
            occupancy_layers_by_rung: self.pool.layout().rung_histogram(),
        }
    }

    /// Record one event at modeled time `ts` — a single branch when
    /// tracing is off, so the hot path is unaffected (`bench hotpath`
    /// guards this stays ≥ 0.98× the recorder-free baseline).
    #[inline]
    fn emit(&self, ts: f64, kind: EventKind) {
        if let Some(t) = &self.trace {
            t.record(&TraceEvent { sim_time_s: ts, kind });
        }
    }

    /// One engine iteration.
    pub fn step(&mut self) -> Result<StepReport> {
        let admissible = self.head_admissible();
        let victim = self.preempt_victim();
        let action = self.scheduler.next_action(
            self.waiting.len(),
            admissible,
            self.running.len(),
            self.cfg.max_batch,
            victim,
        );
        match action {
            Action::Prefill => self.step_prefill(),
            Action::Decode => self.step_decode(),
            Action::Preempt { victim } => self.step_preempt(victim),
            Action::SwapIn => unreachable!("the scheduler never emits SwapIn"),
            Action::Idle => {
                self.stats.idle_iters += 1;
                Ok(StepReport { action, emitted: vec![], finished: vec![] })
            }
        }
    }

    /// Run until all submitted requests complete; returns their outputs.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        let mut guard = 0usize;
        while self.has_work() {
            let r = self.step()?;
            if r.action == Action::Idle {
                guard += 1;
                if guard > 4 {
                    bail!(
                        "engine stalled: {} waiting, {} running, {} free blocks",
                        self.waiting.len(),
                        self.running.len(),
                        self.pool.free_blocks()
                    );
                }
            } else {
                guard = 0;
            }
        }
        Ok(self.take_outputs())
    }

    // ---- internals --------------------------------------------------------

    fn head_admissible(&self) -> bool {
        let Some(&id) = self.waiting.front() else { return false };
        let s = &self.seqs[&id];
        if s.handle.is_some() {
            return true; // already admitted, mid-prefill
        }
        // Conservative reservation: full prompt + generation budget — minus
        // whatever prefix the cache already holds resident (those blocks
        // are adopted, not allocated), and counting unreferenced cached
        // blocks as free since they evict on demand. The matched blocks
        // themselves are excluded from the evictable credit: they are about
        // to be adopted, so counting their tokens off `need` AND their
        // blocks as evictable would double-count capacity. The reservation
        // also covers preempted resumes: a swap-in restores
        // `blocks_for(kv_len)` ≤ this bound, and a recompute re-prefill
        // peaks at the same footprint the original admission reserved.
        let mut need = s.prompt.len() + s.max_new_tokens;
        if self.pool.blocks_for(need) <= self.pool.free_blocks() {
            return true; // fits without touching the cache at all
        }
        let mut avail = self.pool.free_blocks();
        if let Some(pc) = &self.prefix {
            let mut evictable = pc.evictable_blocks(&self.pool);
            // A swapped-out head restores its blocks instead of adopting
            // cached ones, so it earns no prefix credit. A migrated-in
            // head imports its snapshot the same way.
            if !s.swapped && s.migrate_snapshot.is_none() {
                let hit =
                    pc.peek_hit_tokens(&s.seq_tokens, self.prefix_match_cap(s.seq_tokens.len()));
                need -= hit;
                evictable = evictable.saturating_sub(hit / self.pool.block_tokens());
            }
            avail += evictable;
        }
        self.pool.blocks_for(need) <= avail
    }

    // ---- preemption (DESIGN.md §8) ----------------------------------------

    /// Pool blocks the next decode step will allocate: one per sequence
    /// sitting at a block boundary, plus one per sequence whose partial
    /// tail block is shared (copy-on-write copies it on append).
    fn decode_need_blocks(&self) -> usize {
        let bt = self.pool.block_tokens();
        self.running
            .iter()
            .map(|id| {
                let h = self.seqs[id].handle.expect("running seq has a handle");
                let len = self.pool.seq_len(h);
                if len % bt == 0 {
                    1
                } else {
                    let tail = self.pool.seq_blocks(h)[len / bt];
                    usize::from(self.pool.block_ref_count(tail) > 1)
                }
            })
            .sum()
    }

    /// Can the next decode step fit, counting on-demand cache eviction?
    fn decode_blocked(&self) -> bool {
        let need = self.decode_need_blocks();
        if need <= self.pool.free_blocks() {
            return false;
        }
        let evictable =
            self.prefix.as_ref().map(|pc| pc.evictable_blocks(&self.pool)).unwrap_or(0);
        need > self.pool.free_blocks() + evictable
    }

    /// Precision-aware preemption cost of one running victim: swap ships
    /// its resident blocks' quantized bytes; recompute re-prefills the
    /// suffix the prefix index does not already hold.
    fn victim_cost(&self, id: u64) -> VictimCost {
        let s = &self.seqs[&id];
        let h = s.handle.expect("victim has a handle");
        let kv_len = self.pool.seq_len(h);
        // Cache credit uses the same cap resume adoption will: the final
        // chunk always reruns, so even a fully-indexed victim pays that
        // chunk's re-prefill — pricing it as free would pick recompute
        // over a cheaper swap.
        let cached = match &self.prefix {
            Some(pc) => {
                let resident = s.resident_tokens();
                pc.peek_hit_tokens(&resident, self.prefix_match_cap(resident.len()))
            }
            None => 0,
        };
        let cost = VictimCost::estimate(
            self.pool.seq_blocks(h).len(),
            self.pool.block_tokens(),
            self.pool.token_code_bytes(),
            self.pool.token_scale_bytes(),
            kv_len,
            cached,
        );
        if self.swap.disk_tier() {
            // A page-file-backed tier pays the disk round trip on top of
            // PCIe; price it so the swap/recompute choice (and the traced
            // decision record) reflect the mechanism's real modeled cost.
            cost.with_disk_tier()
        } else {
            cost
        }
    }

    /// The mechanism [`Engine::preempt_one`] would actually use for this
    /// victim under the current mode and swap-budget state — Swap mode is
    /// adaptive (each victim's cheaper mechanism, so prefix-cached victims
    /// recompute), and a full swap budget downgrades to recompute.
    fn victim_mechanism(&self, id: u64, cost: &VictimCost) -> PreemptMechanism {
        match self.cfg.preemption_mode {
            PreemptionMode::Abort => unreachable!("abort mode never preempts"),
            PreemptionMode::Recompute => PreemptMechanism::Recompute,
            // Ladder mode's rung fires *before* victim selection
            // (`try_ladder`); once the ladder is exhausted it degrades to
            // the adaptive swap policy for the victims it can no longer
            // save, so the mechanism choice below is shared.
            PreemptionMode::Swap | PreemptionMode::Ladder => {
                let h = self.seqs[&id].handle.expect("victim has a handle");
                match cost.preferred() {
                    PreemptMechanism::Swap if !self.swap.can_hold(self.pool.seq_len(h)) => {
                        PreemptMechanism::Recompute
                    }
                    m => m,
                }
            }
        }
    }

    /// The cost model's cheapest victim among the running batch (None when
    /// the batch is empty or preemption is off). Each candidate is priced
    /// under the mechanism it would *actually* use — including the budget
    /// downgrade — so a budget-blocked "cheap swap" never outbids a victim
    /// whose real (recompute) cost is lower. Ties break youngest-first,
    /// like [`pick_victim`](super::preempt::pick_victim).
    fn choose_victim(&self) -> Option<u64> {
        if self.cfg.preemption_mode == PreemptionMode::Abort {
            return None;
        }
        self.running
            .iter()
            .map(|&id| {
                let cost = self.victim_cost(id);
                (id, cost.cost_of(self.victim_mechanism(id, &cost)))
            })
            .min_by(|a, b| a.1.total_cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|(id, _)| id)
    }

    /// The victim the scheduler should preempt this iteration, or None
    /// when decode fits (or preemption can't help: abort mode, or fewer
    /// than two runners — preempting a sole runner frees exactly the
    /// blocks it would immediately re-claim). A viable ladder rung lifts
    /// the two-runner floor: laddering frees capacity *without* evicting,
    /// so even a sole blocked runner can be saved.
    fn preempt_victim(&self) -> Option<u64> {
        if self.cfg.preemption_mode == PreemptionMode::Abort {
            return None;
        }
        if self.running.len() < 2 && !self.ladder_available() {
            return None;
        }
        if !self.decode_blocked() {
            return None;
        }
        self.choose_victim()
    }

    /// Release a victim's state for a recompute resume: rebuild the token
    /// stream the re-prefill must cover, restart prefill bookkeeping, and
    /// count it. Shared by the Recompute preemption arm and the swap-in
    /// downgrade path, so the two can never drift apart.
    fn release_for_recompute(&mut self, id: u64) {
        let s = self.seqs.get_mut(&id).unwrap();
        s.rebuild_seq_tokens();
        s.prefill_pos = 0;
        s.indexed_blocks = 0;
        self.preempt_stats.recompute_preemptions += 1;
        self.preempt_stats.recomputed_tokens += s.seq_tokens.len();
    }

    /// Preempt one running victim: swap its KV host-ward or release it for
    /// recompute (per mode, cost model, and swap budget), then re-queue it
    /// at the head so it resumes before fresh arrivals.
    fn preempt_one(&mut self, id: u64) -> Result<()> {
        let cost = self.victim_cost(id);
        let mech = self.victim_mechanism(id, &cost);
        let h = self.seqs[&id].handle.expect("victim has a handle");
        if self.trace.is_some() {
            // The decision record: the chosen mechanism's modeled cost,
            // the same victim's cost under the losing mechanism, and the
            // runner-up candidate the cost model passed over.
            let alt = match mech {
                PreemptMechanism::Swap => PreemptMechanism::Recompute,
                _ => PreemptMechanism::Swap,
            };
            let (runner_up, runner_up_cost_s) = self
                .running
                .iter()
                .filter(|&&v| v != id)
                .map(|&v| {
                    let c = self.victim_cost(v);
                    (v, c.cost_of(self.victim_mechanism(v, &c)))
                })
                .min_by(|a, b| a.1.total_cmp(&b.1).then(b.0.cmp(&a.0)))
                .unwrap_or((NO_ID, 0.0));
            self.emit(
                self.stats.sim_time_s,
                EventKind::Preempt {
                    victim: id,
                    mechanism: mech.trace_code(),
                    chosen_cost_s: cost.cost_of(mech),
                    alt_cost_s: cost.cost_of(alt),
                    candidates: self.running.len() as u64,
                    runner_up,
                    runner_up_cost_s,
                },
            );
        }
        self.running.retain(|x| *x != id);
        self.preempt_stats.preemptions += 1;
        match mech {
            PreemptMechanism::Swap => {
                let snap = self.pool.export_seq(h)?;
                // Attribute per-rung bytes from the snapshot's own recorded
                // extents, not the pool's *current* per-token split — the
                // pool may relayout while this snapshot sits host-side, and
                // the attribution must describe the bytes actually shipped.
                let by_rung = snap.bytes_by_rung();
                let bytes = snapshot_bytes(&snap);
                match self.swap.insert(id, snap) {
                    Ok(()) => {
                        for (acc, b) in
                            self.stats.swap_pcie_bytes_by_rung.iter_mut().zip(by_rung)
                        {
                            *acc += b;
                        }
                        let dt = transfer_time_s(bytes);
                        self.emit(
                            self.stats.sim_time_s,
                            EventKind::SwapOut {
                                id,
                                bytes_by_rung: by_rung.map(|b| b as u64),
                                dur_s: dt,
                            },
                        );
                        self.stats.sim_time_s += dt;
                        if self.swap.disk_tier() {
                            for (acc, b) in
                                self.stats.store_disk_bytes_by_rung.iter_mut().zip(by_rung)
                            {
                                *acc += b;
                            }
                            let ddt = disk_transfer_time_s(bytes);
                            self.emit(
                                self.stats.sim_time_s,
                                EventKind::StoreWrite {
                                    id,
                                    bytes_by_rung: by_rung.map(|b| b as u64),
                                    dur_s: ddt,
                                },
                            );
                            self.stats.sim_time_s += ddt;
                        }
                        self.preempt_stats.swap_preemptions += 1;
                        self.seqs.get_mut(&id).unwrap().swapped = true;
                    }
                    // A full page file is backpressure, not corruption:
                    // nothing shipped, so nothing is priced or counted —
                    // the victim falls back to recompute.
                    Err(_) => self.release_for_recompute(id),
                }
            }
            PreemptMechanism::Recompute => self.release_for_recompute(id),
        }
        let s = self.seqs.get_mut(&id).unwrap();
        s.handle = None;
        s.phase = Phase::Waiting;
        s.preempt_count += 1;
        self.pool.free_seq(h);
        // Head of the queue — but never ahead of a mid-prefill admission,
        // whose partial KV must finish before anything else is admitted.
        let head_mid_prefill = self
            .waiting
            .front()
            .is_some_and(|fid| self.seqs[fid].handle.is_some());
        if head_mid_prefill {
            self.waiting.insert(1, id);
        } else {
            self.waiting.push_front(id);
        }
        Ok(())
    }

    /// Execute `Action::Preempt`: evict the scheduler's victim (and any
    /// further victims the cost model must sacrifice until the decode
    /// fits), then run the unblocked decode **in the same iteration** —
    /// re-evaluating first would let admission steal the freed blocks and
    /// livelock the victim in a preempt/readmit cycle.
    fn step_preempt(&mut self, first: u64) -> Result<StepReport> {
        self.stats.preempt_iters += 1;
        // Ladder first: one pool-wide rung down can free the blocks the
        // decode needs without evicting anyone. It restarts every decoding
        // sequence at the narrower layout (the determinism contract wants
        // their whole generation at the *final* precision assignment), so
        // when it fires the batch drains to the waiting queue and re-enters
        // through prefill — no decode runs this iteration.
        if self.ladder_available() {
            let shortfall = self.decode_shortfall().max(1);
            if self.try_ladder(shortfall)? {
                debug_assert!(self.running.is_empty(), "ladder restarts every runner");
                return Ok(StepReport {
                    action: Action::Preempt { victim: first },
                    emitted: vec![],
                    finished: vec![],
                });
            }
        }
        if self.running.len() >= 2 {
            self.preempt_one(first)?;
            while self.running.len() >= 2 && self.decode_blocked() {
                let Some(v) = self.choose_victim() else { break };
                self.preempt_one(v)?;
            }
        }
        // With the ladder exhausted and a sole runner left there is nothing
        // to evict; decode runs anyway and the append failure becomes the
        // structured abort, exactly as in abort mode.
        let rep = self.step_decode()?;
        Ok(StepReport {
            action: Action::Preempt { victim: first },
            emitted: rep.emitted,
            finished: rep.finished,
        })
    }

    // ---- precision laddering (DESIGN.md §10) ------------------------------

    /// Is the ladder switched on for this engine? `--kv-ladder auto` (any
    /// lossless mode) or `--preempt ladder` both arm it.
    fn ladder_enabled(&self) -> bool {
        self.cfg.ladder_policy == LadderPolicy::Auto
            || self.cfg.preemption_mode == PreemptionMode::Ladder
    }

    /// Armed *and* the current layout still has a rung to take.
    fn ladder_available(&self) -> bool {
        self.ladder_enabled() && self.pool.layout().can_ladder()
    }

    /// Blocks the next decode step is short, after cache eviction credit.
    fn decode_shortfall(&self) -> usize {
        let evictable =
            self.prefix.as_ref().map(|pc| pc.evictable_blocks(&self.pool)).unwrap_or(0);
        self.decode_need_blocks()
            .saturating_sub(self.pool.free_blocks() + evictable)
    }

    /// Try a ladder move: walk the rung schedule (least-important
    /// downgradable layer first, per the static importance vector),
    /// deepening the target layout until the capacity it frees covers
    /// `needed_blocks` — one rung rarely suffices when every runner
    /// crosses a block boundary in lockstep. Each candidate is priced as
    /// pool-wide transcode traffic at modeled HBM bandwidth; the move
    /// executes as a *single* relayout to the chosen target (transcoding
    /// kv16→kv4 directly equals transcoding via kv8 bit-for-bit). Returns
    /// whether a move was taken; `false` means even the fully-exhausted
    /// ladder cannot free enough, and the caller falls back to eviction.
    fn try_ladder(&mut self, needed_blocks: usize) -> Result<bool> {
        if !self.ladder_available() {
            return Ok(false);
        }
        let imp = layer_importance(self.model.n_layers);
        let dropped: usize =
            self.running.iter().map(|id| self.seqs[id].generated.len()).sum();
        let mut cursor = self.pool.layout().clone();
        let mut target = None;
        while let Some((next, _layer, _from, _to)) = cursor.ladder_step(&imp) {
            let est = self.pool.relayout_estimate(&next)?;
            let cost = LadderCost::estimate(est.transcoded_bytes, est.gained_blocks, dropped);
            cursor = next;
            if cost.frees_enough(needed_blocks) {
                target = Some((cursor.clone(), cost));
                break;
            }
        }
        let Some((target, cost)) = target else { return Ok(false) };
        // The "nobody evicted" decision record: the pool-wide rung beat
        // every per-victim mechanism, so there is no victim or runner-up.
        self.emit(
            self.stats.sim_time_s,
            EventKind::Preempt {
                victim: NO_ID,
                mechanism: PreemptMechanism::Ladder.trace_code(),
                chosen_cost_s: cost.time_s(),
                alt_cost_s: 0.0,
                candidates: self.running.len() as u64,
                runner_up: NO_ID,
                runner_up_cost_s: 0.0,
            },
        );
        self.execute_ladder(&target)?;
        Ok(true)
    }

    /// Take the rung: invalidate the prefix index (stale-precision blocks
    /// must never be served), restart every resident sequence at the new
    /// layout, drop stale swap snapshots, then transcode the pool in place
    /// and charge the modeled HBM time.
    fn execute_ladder(&mut self, target: &KvLayout) -> Result<()> {
        let from_layout = self.pool.layout().clone();
        let dropped_before = self.preempt_stats.ladder_dropped_tokens;
        // Every resident sequence lives through this event.
        for s in self.seqs.values_mut() {
            if s.handle.is_some() || s.swapped {
                s.ladder_count += 1;
            }
        }

        // The index pins whole chains of blocks; releasing those pins
        // first keeps them out of the transcode walk (they are dead at the
        // new layout either way).
        if let Some(pc) = self.prefix.as_mut() {
            pc.invalidate_for_relayout(&mut self.pool, target.clone());
        }

        // Restart the decode batch: rewind each runner to its resident
        // prompt prefix (transcode makes those codes bit-identical to a
        // fresh prefill at the target layout) and regenerate from there.
        let runners: Vec<u64> = std::mem::take(&mut self.running);
        for &id in &runners {
            self.ladder_restart_resident(id)?;
        }
        // Mid-prefill admissions (including recompute resumes rebuilding
        // their cache) hold pool blocks too; restart them in place — they
        // keep their queue position.
        let waiting_resident: Vec<u64> = self
            .waiting
            .iter()
            .copied()
            .filter(|id| self.seqs[id].handle.is_some())
            .collect();
        for id in waiting_resident {
            self.ladder_restart_resident(id)?;
        }
        // Re-queue the runners at the front (behind a mid-prefill head,
        // whose partial KV must finish first), preserving batch order.
        let head_mid_prefill = self
            .waiting
            .front()
            .is_some_and(|fid| self.seqs[fid].handle.is_some());
        let base = usize::from(head_mid_prefill).min(self.waiting.len());
        for (j, &id) in runners.iter().enumerate() {
            self.waiting.insert(base + j, id);
        }

        // Swap snapshots were exported at the old layout; importing them
        // into the laddered pool would resurrect stale-precision bytes.
        // Drop them and let those victims re-prefill from scratch.
        let swapped: Vec<u64> = self
            .seqs
            .iter()
            .filter(|(_, s)| s.swapped)
            .map(|(&id, _)| id)
            .collect();
        for id in swapped {
            self.swap.drop_entry(id);
            let s = self.seqs.get_mut(&id).unwrap();
            s.swapped = false;
            s.generated.clear();
            s.seq_tokens = s.prompt.clone();
            s.prefill_pos = 0;
            s.indexed_blocks = 0;
            // Reclassify: preserved by the ladder now, not by swap (the
            // per-mechanism buckets keep summing to `preemptions`).
            self.preempt_stats.swap_preemptions -= 1;
            self.preempt_stats.ladder_preemptions += 1;
        }

        // Pending migrated-in snapshots were validated against the
        // pre-rung layout; transcode them along with the pool so their
        // fingerprint still matches at import time. A ladder rung is
        // always a downward move, so the transcode is always legal — and
        // bit-identical to importing first and laddering after.
        for s in self.seqs.values_mut() {
            if let Some(snap) = s.migrate_snapshot.take() {
                s.migrate_snapshot = Some(snap.transcode_to(target)?);
            }
        }

        let report = self.pool.relayout(target)?;
        // The rung re-keys the pool's chain space: re-register it so this
        // engine's future prefix publications land under the new root (and
        // so restarted processes at this rung can adopt them).
        if let Some(store) = &self.store {
            self.store_root = Some(store.register_layout(target, self.pool.block_tokens())?);
        }
        for (acc, b) in
            self.stats.transcode_bytes_by_rung.iter_mut().zip(report.transcoded_bytes_by_rung)
        {
            *acc += b;
        }
        // The rung pair: widest changed source rank → narrowest changed
        // destination rank across the layers this rung touched.
        let (mut rung_from, mut rung_to) = (u8::MAX, 0u8);
        for l in 0..from_layout.n_layers() {
            let (f, t) = (from_layout.prec(l), target.prec(l));
            if f != t {
                rung_from = rung_from.min(f.ladder_rank());
                rung_to = rung_to.max(t.ladder_rank());
            }
        }
        let dt = report.transcoded_bytes as f64 / HBM_BANDWIDTH_BPS;
        self.emit(
            self.stats.sim_time_s,
            EventKind::Ladder {
                rung_from: if rung_from == u8::MAX { 0 } else { rung_from },
                rung_to,
                bytes_by_rung: report.transcoded_bytes_by_rung.map(|b| b as u64),
                gained_blocks: report.gained_blocks as u64,
                dropped_tokens: (self.preempt_stats.ladder_dropped_tokens - dropped_before)
                    as u64,
                to_fingerprint: target.fingerprint(),
                dur_s: dt,
            },
        );
        self.stats.sim_time_s += dt;
        self.preempt_stats.ladder_events += 1;
        self.preempt_stats.ladder_transcoded_bytes += report.transcoded_bytes;
        self.preempt_stats.ladder_freed_bytes += report.gained_blocks
            * target.bytes_per_block(
                self.model.n_kv_heads,
                self.model.head_dim,
                self.pool.block_tokens(),
            );
        Ok(())
    }

    /// Rewind one resident sequence for a post-ladder restart: drop its
    /// generated tokens (they regenerate bit-identically at the final
    /// layout), truncate its KV to the resident prompt prefix below the
    /// final-chunk boundary, and point prefill at the gap. The pool handle
    /// — and the retained, about-to-be-transcoded blocks — stay put.
    fn ladder_restart_resident(&mut self, id: u64) -> Result<()> {
        let bt = self.pool.block_tokens();
        let (h, dropped) = {
            let s = self.seqs.get_mut(&id).unwrap();
            let d = s.generated.len();
            s.generated.clear();
            s.seq_tokens = s.prompt.clone();
            (s.handle.expect("resident seq has a handle"), d)
        };
        let cap = self.prefix_match_cap(self.seqs[&id].prompt.len());
        let keep = cap.min(self.pool.seq_len(h) / bt * bt);
        self.pool.truncate_seq(h, keep)?;
        let s = self.seqs.get_mut(&id).unwrap();
        s.prefill_pos = keep;
        s.indexed_blocks = 0;
        s.phase = Phase::Prefilling;
        if dropped > 0 {
            // A true victim: it had started generating and loses that work
            // to the restart (the ladder's re-decode cost).
            s.preempt_count += 1;
            self.preempt_stats.preemptions += 1;
            self.preempt_stats.ladder_preemptions += 1;
            self.preempt_stats.ladder_dropped_tokens += dropped;
        }
        Ok(())
    }

    /// Restore a swapped-out head-of-queue sequence into the pool. Returns
    /// `Ok(None)` — after downgrading the victim to recompute — when the
    /// pool cannot take the restore even after cache eviction; the caller
    /// then proceeds with a normal (re-)prefill admission.
    fn try_swap_in(&mut self, id: u64) -> Result<Option<StepReport>> {
        let needed = self.pool.blocks_for(self.swap.tokens_of(id));
        self.make_room(needed);
        if self.pool.free_blocks() < needed {
            self.swap.drop_entry(id);
            self.seqs.get_mut(&id).unwrap().swapped = false;
            // Reclassify: this victim ended up preserved by recompute, not
            // swap, so the per-mechanism buckets keep summing to
            // `preemptions` (and `swap_fraction` stays honest).
            self.preempt_stats.swap_preemptions -= 1;
            self.release_for_recompute(id);
            return Ok(None);
        }
        let snap = self
            .swap
            .take(id)?
            .ok_or_else(|| anyhow!("swapped head {id} has no store entry"))?;
        // Ladder rungs drop swapped entries before relayouting, so the
        // snapshot's layout normally matches the pool; a shared disk store
        // could still hand back an older-generation extent, so transcode
        // defensively rather than let import fail.
        let snap = if snap.layout.fingerprint() == self.pool.layout().fingerprint() {
            snap
        } else {
            snap.transcode_to(self.pool.layout())?
        };
        let handle = self.pool.alloc_seq();
        self.pool.import_seq(handle, &snap)?;
        // Same rule as swap-out: bytes come from the snapshot's recorded
        // extents, so Σ per-rung always equals the headline transfer even
        // if the pool relayouted while the sequence was swapped.
        let by_rung = snap.bytes_by_rung();
        for (acc, b) in self.stats.swap_pcie_bytes_by_rung.iter_mut().zip(by_rung) {
            *acc += b;
        }
        let bytes = snapshot_bytes(&snap);
        if self.swap.disk_tier() {
            // The disk leg runs first (page file → host), then PCIe.
            for (acc, b) in self.stats.store_disk_bytes_by_rung.iter_mut().zip(by_rung) {
                *acc += b;
            }
            let ddt = disk_transfer_time_s(bytes);
            self.emit(
                self.stats.sim_time_s,
                EventKind::StoreRead {
                    id,
                    bytes_by_rung: by_rung.map(|b| b as u64),
                    dur_s: ddt,
                },
            );
            self.stats.sim_time_s += ddt;
        }
        let dt = transfer_time_s(bytes);
        self.emit(
            self.stats.sim_time_s,
            EventKind::SwapIn { id, bytes_by_rung: by_rung.map(|b| b as u64), dur_s: dt },
        );
        self.stats.sim_time_s += dt;
        let restored = self.pool.seq_blocks(handle).len();
        let s = self.seqs.get_mut(&id).unwrap();
        debug_assert!(s.decoding_started(), "only decoding victims are swapped");
        s.handle = Some(handle);
        s.swapped = false;
        s.swapped_in_blocks += restored;
        s.phase = Phase::Decoding;
        self.waiting.pop_front();
        self.running.push(id);
        Ok(Some(StepReport { action: Action::SwapIn, emitted: vec![], finished: vec![] }))
    }

    /// Import a migrated-in head-of-queue sequence's snapshot into the
    /// pool. Returns `Ok(None)` — after downgrading the arrival to a full
    /// re-prefill — when the pool cannot take the import even after cache
    /// eviction. The downgrade touches **no** preemption counter
    /// (migration is placement, not pressure): only
    /// `MigrationStats::migrate_in_downgrades` records it, so
    /// `swap_preemptions` can never underflow on this path.
    fn try_migrate_in(&mut self, id: u64) -> Result<Option<StepReport>> {
        let tokens =
            self.seqs[&id].migrate_snapshot.as_ref().expect("caller checked the head").len;
        let needed = self.pool.blocks_for(tokens);
        self.make_room(needed);
        if self.pool.free_blocks() < needed {
            let s = self.seqs.get_mut(&id).unwrap();
            s.migrate_snapshot = None;
            s.rebuild_seq_tokens();
            s.prefill_pos = 0;
            s.indexed_blocks = 0;
            self.migration_stats.migrate_in_downgrades += 1;
            return Ok(None);
        }
        let snap = self
            .seqs
            .get_mut(&id)
            .unwrap()
            .migrate_snapshot
            .take()
            .expect("checked above");
        let handle = self.pool.alloc_seq();
        self.pool.import_seq(handle, &snap)?;
        let by_rung = snap.bytes_by_rung();
        for (acc, b) in self.stats.migrate_pcie_bytes_by_rung.iter_mut().zip(by_rung) {
            *acc += b;
        }
        let bytes = snapshot_bytes(&snap);
        let dt = transfer_time_s(bytes);
        self.emit(
            self.stats.sim_time_s,
            EventKind::MigrateIn { id, bytes_by_rung: by_rung.map(|b| b as u64), dur_s: dt },
        );
        self.stats.sim_time_s += dt;
        self.migration_stats.migrated_in += 1;
        self.migration_stats.migrated_in_bytes += bytes;
        let s = self.seqs.get_mut(&id).unwrap();
        debug_assert!(s.decoding_started(), "a migrated snapshot implies a sampled token");
        s.handle = Some(handle);
        s.phase = Phase::Decoding;
        self.waiting.pop_front();
        self.running.push(id);
        Ok(Some(StepReport { action: Action::SwapIn, emitted: vec![], finished: vec![] }))
    }

    /// The effective prefill chunk: an uncached prefill's chunk boundaries
    /// fall on multiples of this (the configured chunk, rounded to the
    /// compiled bucket that actually executes it).
    fn effective_prefill_chunk(&self) -> usize {
        let chunks = &self.backend.plan().prefill_chunks;
        chunks
            .iter()
            .copied()
            .filter(|&c| c >= self.cfg.prefill_chunk)
            .min()
            .unwrap_or_else(|| chunks.iter().copied().max().expect("no prefill chunks"))
    }

    /// Longest prefix the cache may serve for a `prompt_len`-token prompt:
    /// capped at the final chunk boundary — the last chunk always reruns,
    /// so its logits (and the sampled first token) are bit-identical to an
    /// uncached run at every KV precision — and rounded down to whole
    /// blocks (the index only holds full blocks).
    fn prefix_match_cap(&self, prompt_len: usize) -> usize {
        let eff = self.effective_prefill_chunk();
        let cap = (prompt_len.saturating_sub(1) / eff) * eff;
        cap - cap % self.pool.block_tokens()
    }

    /// Evict unreferenced prefix-cache blocks until at least `needed`
    /// blocks are free (or nothing more can be evicted).
    fn make_room(&mut self, needed: usize) {
        if let Some(pc) = self.prefix.as_mut() {
            while self.pool.free_blocks() < needed {
                if !pc.evict_one(&mut self.pool) {
                    break;
                }
            }
        }
    }

    /// Plan the next prefill chunk for `id`: (handle, base position,
    /// bucket-padded token ids, compiled bucket, real token count). Chunk
    /// ends align to absolute multiples of the effective chunk, so a
    /// prefix-seeded prefill (`prefill_pos > 0`) walks the same chunk
    /// boundaries — and computes the same logits — as an uncached run of
    /// the same prompt.
    fn chunk_plan(&self, id: u64) -> (SeqHandle, usize, Vec<i32>, usize, usize) {
        let s = &self.seqs[&id];
        let rem = s.remaining_prompt();
        let eff = self.effective_prefill_chunk();
        let want = rem.min(eff - s.prefill_pos % eff);
        let bucket = self.prefill_bucket(want);
        let real = want.min(bucket);
        let mut toks: Vec<i32> = s.seq_tokens[s.prefill_pos..s.prefill_pos + real].to_vec();
        toks.resize(bucket, 0);
        (s.handle.unwrap(), s.prefill_pos, toks, bucket, real)
    }

    /// Fresh pool blocks appending `real` more tokens to `handle` claims.
    fn chunk_need(&self, handle: SeqHandle, real: usize) -> usize {
        self.pool
            .blocks_for(self.pool.seq_len(handle) + real)
            .saturating_sub(self.pool.seq_blocks(handle).len())
    }

    /// Pick the compiled prefill bucket for `remaining` prompt tokens.
    fn prefill_bucket(&self, remaining: usize) -> usize {
        let chunks = &self.backend.plan().prefill_chunks;
        *chunks
            .iter()
            .filter(|&&c| c >= remaining.min(self.cfg.prefill_chunk))
            .min()
            .unwrap_or_else(|| chunks.iter().max().expect("no prefill chunks"))
    }

    /// Pick the compiled decode batch for `n` live sequences.
    fn decode_batch_size(&self, n: usize) -> Result<usize> {
        self.backend
            .plan()
            .decode_batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| anyhow!("no compiled decode batch >= {n}"))
    }

    /// Pick the compiled decode context bucket covering `need` tokens —
    /// short contexts avoid the full max_seq_len attention scan (§Perf).
    fn decode_t_bucket(&self, need: usize) -> Result<usize> {
        self.backend
            .plan()
            .decode_t
            .iter()
            .copied()
            .filter(|&t| t >= need)
            .min()
            .ok_or_else(|| anyhow!("context {need} exceeds every compiled decode bucket"))
    }

    fn step_prefill(&mut self) -> Result<StepReport> {
        let id = *self.waiting.front().expect("scheduler said Prefill");

        // A swap-preempted head resumes by restoring its blocks, not by
        // prefilling; if the pool can't take the restore the victim is
        // downgraded to recompute and falls through to a normal admission.
        if self.seqs[&id].swapped {
            if let Some(report) = self.try_swap_in(id)? {
                self.stats.swap_in_iters += 1;
                return Ok(report);
            }
        }
        // A migrated-in head imports its shipped snapshot the same way; a
        // failed import downgrades to the re-prefill below.
        if self.seqs[&id].migrate_snapshot.is_some() {
            if let Some(report) = self.try_migrate_in(id)? {
                self.stats.migrate_in_iters += 1;
                return Ok(report);
            }
        }
        self.stats.prefill_iters += 1;

        let m = self.model.clone();
        let t_pad = m.max_seq_len;

        // Admit if new: allocate the sequence and consult the prefix index
        // before any prefill work — matched full blocks are adopted
        // (ref-counted) and their tokens never rerun. `seq_tokens` is the
        // prompt for fresh requests and prompt + generated-so-far for
        // recompute resumes, whose own prompt blocks often still sit in
        // the index (that is what makes their recompute cheap).
        if self.seqs[&id].handle.is_none() {
            let cap = self.prefix_match_cap(self.seqs[&id].seq_tokens.len());
            let handle = self.pool.alloc_seq();
            let mut hit_tokens = 0usize;
            if self.prefix.is_some() {
                // Host-global store first: adopt its chain when it resolves
                // strictly deeper than the local in-pool index would — the
                // bytes then come off disk (priced below) and immediately
                // seed the local index for this replica's siblings.
                let local_peek = self
                    .prefix
                    .as_ref()
                    .map(|pc| pc.peek_hit_tokens(&self.seqs[&id].seq_tokens, cap))
                    .unwrap_or(0);
                let resolved = self.store.as_ref().and_then(|st| {
                    resolve_shared_prefix(
                        st,
                        &self.seqs[&id].seq_tokens,
                        self.pool.layout(),
                        self.pool.block_tokens(),
                        cap,
                    )
                });
                if let Some(hit) = resolved.filter(|h| h.tokens > local_peek) {
                    let st = self.store.clone().expect("hit resolved from a store");
                    // A block evicted between resolve and fetch is a miss,
                    // not an error; corruption propagates (fail closed).
                    if let Some((snap, receipt)) = fetch_chain(&st, &hit)? {
                        if snap.kv_heads == m.n_kv_heads && snap.head_dim == m.head_dim {
                            let snap = if snap.layout.fingerprint()
                                == self.pool.layout().fingerprint()
                            {
                                snap
                            } else {
                                // Cross-layout adoption: a wider replica's
                                // blocks re-quantize bit-identically to a
                                // fresh append at this pool's layout.
                                snap.transcode_to(self.pool.layout())?
                            };
                            self.pool.import_seq(handle, &snap)?;
                            let by_rung = receipt.bytes_by_rung;
                            for (acc, b) in
                                self.stats.store_disk_bytes_by_rung.iter_mut().zip(by_rung)
                            {
                                *acc += b;
                            }
                            let bytes = receipt.snapshot_bytes();
                            let ddt = disk_transfer_time_s(bytes);
                            self.emit(
                                self.stats.sim_time_s,
                                EventKind::StoreRead {
                                    id,
                                    bytes_by_rung: by_rung.map(|b| b as u64),
                                    dur_s: ddt,
                                },
                            );
                            // Disk → host, then host → device over PCIe.
                            self.stats.sim_time_s += ddt + transfer_time_s(bytes);
                            hit_tokens = snap.len;
                            self.stats.store_prefix_hits += 1;
                            self.stats.store_prefix_hit_tokens += snap.len;
                            let n_blocks = snap.len / self.pool.block_tokens();
                            let blocks: Vec<usize> =
                                self.pool.seq_blocks(handle)[..n_blocks].to_vec();
                            let s = &self.seqs[&id];
                            if let Some(pc) = self.prefix.as_mut() {
                                pc.insert(&mut self.pool, &s.seq_tokens[..snap.len], &blocks);
                            }
                        }
                    }
                }
            }
            if hit_tokens == 0 {
                if let Some(pc) = self.prefix.as_mut() {
                    let (tokens, blocks) = pc.lookup(&self.seqs[&id].seq_tokens, cap);
                    if tokens > 0 {
                        self.pool.adopt_blocks(handle, &blocks, tokens)?;
                        hit_tokens = tokens;
                    }
                }
            }
            self.emit(
                self.stats.sim_time_s,
                EventKind::PrefixLookup {
                    id,
                    hit: hit_tokens > 0,
                    blocks: (hit_tokens / self.pool.block_tokens()) as u64,
                    tokens: hit_tokens as u64,
                    fingerprint: self.pool.layout().fingerprint(),
                },
            );
            let s = self.seqs.get_mut(&id).unwrap();
            s.handle = Some(handle);
            s.phase = Phase::Prefilling;
            s.prefill_pos = hit_tokens;
            if !s.decoding_started() {
                // First admission only: resumes keep reporting the hit
                // their original admission earned.
                s.prefix_hit_tokens = hit_tokens;
            }
            // Adopted blocks are already in the index by definition.
            s.indexed_blocks = hit_tokens / self.pool.block_tokens();
            self.stats.prefill_tokens_skipped += hit_tokens;
        }

        let (mut handle, mut pos, mut chunk_tokens, mut bucket, mut real) = self.chunk_plan(id);

        // Make room for the chunk *before* the backend runs (its emitted
        // codes must match the pool layout at append time): evict
        // unreferenced cached blocks, then — still short — take a ladder
        // rung, then sacrifice running victims (the prefill-side analogue
        // of `Action::Preempt`). The rung restarts this very sequence at
        // the narrower layout, so the chunk is re-planned after it.
        let mut new_blocks = self.chunk_need(handle, real);
        self.make_room(new_blocks);
        if self.pool.free_blocks() < new_blocks
            && self.try_ladder(new_blocks - self.pool.free_blocks())?
        {
            (handle, pos, chunk_tokens, bucket, real) = self.chunk_plan(id);
            new_blocks = self.chunk_need(handle, real);
            self.make_room(new_blocks);
        }
        if self.cfg.preemption_mode != PreemptionMode::Abort {
            while self.pool.free_blocks() < new_blocks && !self.running.is_empty() {
                let Some(v) = self.choose_victim() else { break };
                self.preempt_one(v)?;
                self.make_room(new_blocks);
            }
        }

        // Gather the (possibly empty) past context for this sequence.
        let layout = self.pool.layout().clone();
        let sum_rb = layout.sum_row_bytes(m.head_dim);
        let sdim = m.n_layers * m.n_kv_heads * t_pad;
        let mut k_codes = vec![0u8; m.n_kv_heads * t_pad * sum_rb];
        let mut v_codes = vec![0u8; m.n_kv_heads * t_pad * sum_rb];
        let mut k_scales = vec![1f32; sdim];
        let mut v_scales = vec![1f32; sdim];
        let plan = self.pool.plan_gather(&[Some(handle)], t_pad)?;
        self.pool
            .execute_gather(&plan, &mut k_codes, &mut k_scales, &mut v_codes, &mut v_scales)?;
        let gather_by_rung = plan.hbm_bytes_by_rung();
        self.stats.gather_hbm_bytes += plan.hbm_bytes();
        for (acc, b) in self.stats.gather_hbm_bytes_by_rung.iter_mut().zip(gather_by_rung) {
            *acc += b;
        }

        let chunk_start_s = self.stats.sim_time_s;
        let out: StepOutputs = self.backend.prefill(&PrefillArgs {
            tokens: &chunk_tokens,
            real,
            pos,
            t_pad,
            layout: &layout,
            k_codes: &k_codes,
            k_scales: &k_scales,
            v_codes: &v_codes,
            v_scales: &v_scales,
        })?;
        self.stats.sim_time_s += out.sim_time_s;

        if let Err(e) = self.pool.append_chunk(
            handle,
            real,
            bucket,
            &out.k_codes,
            &out.k_scales,
            &out.v_codes,
            &out.v_scales,
        ) {
            // The chunk ran (gather + backend time are charged) but
            // appended nothing — `tokens: 0` keeps Σ PrefillChunk.tokens
            // == `prompt_tokens` exact.
            self.emit(
                chunk_start_s,
                EventKind::PrefillChunk {
                    id,
                    tokens: 0,
                    t_pad: t_pad as u64,
                    gather_by_rung: gather_by_rung.map(|b| b as u64),
                    generated: 0,
                    dur_s: out.sim_time_s,
                },
            );
            return self.abort(id, e);
        }

        // Index the sequence's now-complete full blocks so other requests
        // can start sharing them immediately, even mid-prefill. Chunks
        // that complete no new full block skip the chain walk.
        if self.prefix.is_some() {
            let bt = self.pool.block_tokens();
            let n_full = (self.seqs[&id].prefill_pos + real) / bt;
            if n_full > self.seqs[&id].indexed_blocks {
                let blocks: Vec<usize> = self.pool.seq_blocks(handle)[..n_full].to_vec();
                let s = &self.seqs[&id];
                if let Some(pc) = self.prefix.as_mut() {
                    pc.insert(&mut self.pool, &s.seq_tokens[..n_full * bt], &blocks);
                }
                // Publish the newly completed blocks to the host-global
                // store so other replicas — and restarted processes — can
                // adopt them. Chain keys another replica already published
                // are skipped; a full store skips silently (backpressure,
                // not failure: `rejected_full` counts it store-side).
                if let (Some(store), Some(root)) = (self.store.clone(), self.store_root) {
                    let prev = self.seqs[&id].indexed_blocks;
                    let keys = chain_keys_under(
                        root,
                        &self.seqs[&id].seq_tokens[..n_full * bt],
                        bt,
                        n_full,
                    );
                    let mut exported: Option<crate::kvcache::SeqSnapshot> = None;
                    let mut merged: Option<StoreReceipt> = None;
                    let mut published = 0usize;
                    for b in prev..n_full {
                        if store.contains_prefix(keys[b]) {
                            continue;
                        }
                        if exported.is_none() {
                            exported = Some(self.pool.export_seq(handle)?);
                        }
                        let block_snap =
                            exported.as_ref().unwrap().slice_tokens(b * bt, bt)?;
                        if let Some(receipt) =
                            store.publish_prefix_block(root, keys[b], &block_snap)?
                        {
                            published += 1;
                            match merged.as_mut() {
                                Some(acc) => acc.merge(&receipt),
                                None => merged = Some(receipt),
                            }
                        }
                    }
                    if let Some(receipt) = merged {
                        self.stats.store_published_blocks += published;
                        let by_rung = receipt.bytes_by_rung;
                        for (acc, b) in
                            self.stats.store_disk_bytes_by_rung.iter_mut().zip(by_rung)
                        {
                            *acc += b;
                        }
                        let ddt = disk_transfer_time_s(receipt.snapshot_bytes());
                        self.emit(
                            self.stats.sim_time_s,
                            EventKind::StoreWrite {
                                id,
                                bytes_by_rung: by_rung.map(|b| b as u64),
                                dur_s: ddt,
                            },
                        );
                        self.stats.sim_time_s += ddt;
                    }
                }
                self.seqs.get_mut(&id).unwrap().indexed_blocks = n_full;
            }
        }

        let mut emitted = vec![];
        let mut finished = vec![];
        {
            let sim_now = self.stats.sim_time_s;
            let s = self.seqs.get_mut(&id).unwrap();
            s.prefill_pos += real;
            self.stats.prompt_tokens += real;
            if s.remaining_prompt() == 0 {
                if s.decoding_started() {
                    // Recompute resume: the cache is rebuilt; generation
                    // already has its next input token, so the final
                    // chunk's logits are discarded rather than re-sampled.
                    s.phase = Phase::Decoding;
                    self.waiting.pop_front();
                    self.running.push(id);
                } else {
                    // Prompt done: sample the first token from the last
                    // real row.
                    let v = m.vocab_size;
                    let row = &out.logits[(real - 1) * v..real * v];
                    let tok = self.sampler.sample(row, &mut self.rng);
                    s.generated.push(tok);
                    s.first_token = Some(Instant::now());
                    s.first_token_sim_s = Some(sim_now);
                    s.phase = Phase::Decoding;
                    emitted.push((id, tok));
                    self.stats.tokens_generated += 1;
                    self.waiting.pop_front();
                    if let Some(reason) = s.should_finish() {
                        finished.push(id);
                        self.finish(id, reason);
                    } else {
                        self.running.push(id);
                    }
                }
            }
        }
        self.emit(
            chunk_start_s,
            EventKind::PrefillChunk {
                id,
                tokens: real as u64,
                t_pad: t_pad as u64,
                gather_by_rung: gather_by_rung.map(|b| b as u64),
                generated: emitted.len() as u64,
                dur_s: out.sim_time_s,
            },
        );
        Ok(StepReport { action: Action::Prefill, emitted, finished })
    }

    fn step_decode(&mut self) -> Result<StepReport> {
        self.stats.decode_iters += 1;
        let m = self.model.clone();
        let ids: Vec<u64> = self.running.clone();
        let n = ids.len();
        assert!(n > 0, "scheduler said Decode with empty batch");
        let bsize = self.decode_batch_size(n)?;
        self.stats.padded_slots += bsize - n;

        let mut tokens = vec![0i32; bsize];
        let mut kv_len = vec![1i32; bsize];
        let mut handles: Vec<Option<SeqHandle>> = vec![None; bsize];
        let mut t_need = 2usize; // kv_len + 1 for the inserted token
        for (i, id) in ids.iter().enumerate() {
            let s = &self.seqs[id];
            tokens[i] = s.next_input_token();
            let len = self.pool.seq_len(s.handle.unwrap());
            kv_len[i] = len as i32;
            t_need = t_need.max(len + 1);
            handles[i] = s.handle;
        }
        let t_pad = self.decode_t_bucket(t_need)?;

        let layout = self.pool.layout().clone();
        let sum_rb = layout.sum_row_bytes(m.head_dim);
        let sdim = m.n_layers * bsize * m.n_kv_heads * t_pad;
        let mut k_codes = vec![0u8; bsize * m.n_kv_heads * t_pad * sum_rb];
        let mut v_codes = vec![0u8; bsize * m.n_kv_heads * t_pad * sum_rb];
        let mut k_scales = vec![1f32; sdim];
        let mut v_scales = vec![1f32; sdim];
        let plan = self.pool.plan_gather(&handles, t_pad)?;
        self.pool
            .execute_gather(&plan, &mut k_codes, &mut k_scales, &mut v_codes, &mut v_scales)?;
        let gather_by_rung = plan.hbm_bytes_by_rung();
        self.stats.gather_hbm_bytes += plan.hbm_bytes();
        for (acc, b) in self.stats.gather_hbm_bytes_by_rung.iter_mut().zip(gather_by_rung) {
            *acc += b;
        }

        let iter_start_s = self.stats.sim_time_s;
        let out: StepOutputs = self.backend.decode(&DecodeArgs {
            tokens: &tokens,
            kv_len: &kv_len,
            t_pad,
            layout: &layout,
            k_codes: &k_codes,
            k_scales: &k_scales,
            v_codes: &v_codes,
            v_scales: &v_scales,
        })?;
        self.stats.sim_time_s += out.sim_time_s;

        // Sequences at a block boundary (or on a shared CoW tail) will
        // allocate on append; evict unreferenced cached blocks first if
        // the free list is dry. Same count `decode_blocked` used to judge
        // feasibility — the two must never disagree.
        let need_blocks = self.decode_need_blocks();
        self.make_room(need_blocks);

        // Append each live sequence's new KV codes ([L,B,Hkv,rb_l] layout,
        // layer-major with per-layer row strides).
        let mut emitted = vec![];
        let mut finished = vec![];
        for (i, id) in ids.iter().enumerate() {
            let handle = self.seqs[id].handle.unwrap();
            let mut kc = vec![0u8; m.n_kv_heads * sum_rb];
            let mut vc = vec![0u8; m.n_kv_heads * sum_rb];
            let mut ks = vec![0f32; m.n_layers * m.n_kv_heads];
            let mut vs = vec![0f32; m.n_layers * m.n_kv_heads];
            for l in 0..m.n_layers {
                let rb_l = layout.row_bytes(l, m.head_dim);
                let per = m.n_kv_heads * rb_l;
                let src = bsize * m.n_kv_heads * layout.prefix_row_bytes(l, m.head_dim) + i * per;
                let dst = m.n_kv_heads * layout.prefix_row_bytes(l, m.head_dim);
                kc[dst..dst + per].copy_from_slice(&out.k_codes[src..src + per]);
                vc[dst..dst + per].copy_from_slice(&out.v_codes[src..src + per]);
                let ssrc = (l * bsize + i) * m.n_kv_heads;
                ks[l * m.n_kv_heads..(l + 1) * m.n_kv_heads]
                    .copy_from_slice(&out.k_scales[ssrc..ssrc + m.n_kv_heads]);
                vs[l * m.n_kv_heads..(l + 1) * m.n_kv_heads]
                    .copy_from_slice(&out.v_scales[ssrc..ssrc + m.n_kv_heads]);
            }
            if let Err(e) = self.pool.append_token(handle, &kc, &ks, &vc, &vs) {
                // KV exhausted mid-flight. With swap/recompute preemption
                // `Action::Preempt` makes room before decode runs, so this
                // is the abort-mode overload path (or a sole runner no
                // preemption could save): finish the sequence with its
                // partial generation and a structured reason, keep the
                // batch going.
                self.running.retain(|x| x != id);
                let s = self.seqs.get_mut(id).unwrap();
                s.abort_reason = Some(format!("kv pool exhausted mid-decode: {e}"));
                self.finish(*id, FinishReason::Aborted);
                self.stats.aborted += 1;
                self.preempt_stats.oom_aborts += 1;
                finished.push(*id);
                continue;
            }

            let v = m.vocab_size;
            let tok = self.sampler.sample(&out.logits[i * v..(i + 1) * v], &mut self.rng);
            let s = self.seqs.get_mut(id).unwrap();
            s.generated.push(tok);
            emitted.push((*id, tok));
            self.stats.tokens_generated += 1;
            if let Some(reason) = s.should_finish() {
                self.running.retain(|x| x != id);
                self.finish(*id, reason);
                finished.push(*id);
            }
        }
        self.emit(
            iter_start_s,
            EventKind::DecodeIter {
                batch: n as u64,
                padded_slots: (bsize - n) as u64,
                t_pad: t_pad as u64,
                generated: emitted.len() as u64,
                gather_by_rung: gather_by_rung.map(|b| b as u64),
                dur_s: out.sim_time_s,
            },
        );
        Ok(StepReport { action: Action::Decode, emitted, finished })
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let sim_now = self.stats.sim_time_s;
        let final_kv_layout = self.pool.layout().to_string();
        self.emit(
            sim_now,
            EventKind::Finish {
                id,
                reason: match reason {
                    FinishReason::Length => 0,
                    FinishReason::Stop => 1,
                    FinishReason::Aborted => 2,
                },
                tokens: self.seqs[&id].generated.len() as u64,
                latency_s: sim_now - self.seqs[&id].submitted_sim_s,
            },
        );
        if let Some(h) = self.seqs.get_mut(&id).unwrap().handle.take() {
            // Disaggregated handoff: a prefill-tier sequence exports its
            // byte-exact, layout-tagged KV before the blocks are freed, so
            // a decode replica can import the very cache this one built.
            // Aborted sequences ship nothing.
            if self.seqs[&id].export_on_finish && reason != FinishReason::Aborted {
                let snap = self
                    .pool
                    .export_seq(h)
                    .expect("exporting a finished sequence's live KV");
                let by_rung = snap.bytes_by_rung();
                for (acc, b) in self.stats.migrate_pcie_bytes_by_rung.iter_mut().zip(by_rung) {
                    *acc += b;
                }
                let bytes = snapshot_bytes(&snap);
                let dt = transfer_time_s(bytes);
                self.emit(
                    self.stats.sim_time_s,
                    EventKind::MigrateOut {
                        id,
                        bytes_by_rung: by_rung.map(|b| b as u64),
                        dur_s: dt,
                    },
                );
                self.stats.sim_time_s += dt;
                self.migration_stats.migrated_out += 1;
                self.migration_stats.migrated_out_bytes += bytes;
                self.migration_exports.push((id, snap));
            }
            self.pool.free_seq(h);
        } else if self.seqs[&id].swapped {
            // The sequence ended while its KV sat host-side (e.g. a client
            // cancel of a swapped victim). Release the entry without a
            // swap-in: nothing crosses PCIe, so nothing is priced — and the
            // budget blocks come back instead of leaking. The swap-out
            // stays counted: those bytes really shipped.
            self.swap.drop_entry(id);
            self.seqs.get_mut(&id).unwrap().swapped = false;
        }
        let s = self.seqs.get_mut(&id).unwrap();
        s.phase = Phase::Finished(reason);
        let now = Instant::now();
        self.outputs.push(RequestOutput {
            id,
            tokens: s.generated.clone(),
            finish: reason,
            ttft: s
                .first_token
                .map(|t| t.duration_since(s.submitted).as_secs_f64())
                .unwrap_or(f64::NAN),
            latency: now.duration_since(s.submitted).as_secs_f64(),
            ttft_sim: s
                .first_token_sim_s
                .map(|t| t - s.submitted_sim_s)
                .unwrap_or(f64::NAN),
            latency_sim: sim_now - s.submitted_sim_s,
            prompt_len: s.prompt.len(),
            prefix_hit_tokens: s.prefix_hit_tokens,
            preempt_count: s.preempt_count,
            swapped_in_blocks: s.swapped_in_blocks,
            ladder_count: s.ladder_count,
            final_kv_layout,
            abort_reason: s.abort_reason.take(),
        });
        self.seqs.remove(&id);
    }

    fn abort(&mut self, id: u64, err: anyhow::Error) -> Result<StepReport> {
        self.waiting.retain(|x| *x != id);
        self.running.retain(|x| *x != id);
        self.seqs.get_mut(&id).expect("aborting a live sequence").abort_reason =
            Some(err.to_string());
        self.finish(id, FinishReason::Aborted);
        self.stats.aborted += 1;
        self.preempt_stats.oom_aborts += 1;
        eprintln!("request {id} aborted: {err}");
        Ok(StepReport { action: Action::Prefill, emitted: vec![], finished: vec![id] })
    }

    /// Cancel an in-flight request on behalf of the client. Returns `false`
    /// when `id` is unknown or already finished. The sequence is finished
    /// with [`FinishReason::Aborted`] from whatever state it is in —
    /// queued, running, swapped-out, or pending-import — releasing pool
    /// blocks and (via [`Engine::finish`]) any host-side swap entry
    /// *without* pricing a swap-in that never happens.
    pub fn cancel(&mut self, id: u64) -> bool {
        if !self.seqs.contains_key(&id) {
            return false;
        }
        self.waiting.retain(|x| *x != id);
        self.running.retain(|x| *x != id);
        self.seqs.get_mut(&id).unwrap().abort_reason = Some("cancelled by client".into());
        self.finish(id, FinishReason::Aborted);
        self.stats.aborted += 1;
        true
    }
}

//! The serving engine: continuous batching over the PJRT-backed model.
//!
//! One `Engine` owns the runtime (compiled AOT graphs + weights), the paged
//! quantized KV pool, the scheduler, and all in-flight sequence state. Each
//! `step()` runs exactly one iteration — a prefill chunk or a decode batch —
//! mirroring iteration-level scheduling (Orca) with chunked prefill
//! (Sarathi) and paged KV (vLLM), the serving substrate the paper's §5
//! evaluation assumes.
//!
//! Dataflow per decode step:
//!   gather quantized KV from the pool → padded `[L,B,Hkv,T,·]` tensors →
//!   PJRT execute (the Layer-1 attention kernel dequantizes on the fly) →
//!   sample logits → append the graph-emitted quantized KV codes for the
//!   new token back into the pool (no Rust-side re-quantization).

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::request::{FinishReason, Phase, Request, RequestOutput, SeqState};
use super::sampler::Sampler;
use super::scheduler::{Action, Scheduler};
use crate::config::{DType, EngineConfig};
use crate::kvcache::{KvPool, KvPrecision, SeqHandle};
use crate::runtime::manifest::Manifest;
use crate::runtime::{Dt, HostTensor, Runtime};
use crate::util::rng::Rng;

/// What one engine iteration did.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub action: Action,
    /// (request id, token) pairs emitted this step.
    pub emitted: Vec<(u64, i32)>,
    /// Requests that finished this step.
    pub finished: Vec<u64>,
}

/// Aggregate engine counters.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub prefill_iters: usize,
    pub decode_iters: usize,
    pub idle_iters: usize,
    pub tokens_generated: usize,
    pub prompt_tokens: usize,
    /// Decode-batch slots wasted on padding (fixed compiled batch sizes).
    pub padded_slots: usize,
    pub aborted: usize,
}

/// The engine.
pub struct Engine {
    runtime: Runtime,
    pool: KvPool,
    cfg: EngineConfig,
    wprec: &'static str,
    kv_key: &'static str,
    scheduler: Scheduler,
    sampler: Sampler,
    rng: Rng,
    seqs: BTreeMap<u64, SeqState>,
    waiting: VecDeque<u64>,
    running: Vec<u64>,
    next_id: u64,
    outputs: Vec<RequestOutput>,
    pub stats: EngineStats,
}

impl Engine {
    /// Load artifacts and construct an engine for `cfg.precision`.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let runtime = Runtime::load(&cfg.artifacts_dir)?;
        let m = &runtime.manifest.model;

        let wprec: &'static str = match cfg.precision.weight {
            DType::Int4 => "w4",
            DType::F16 | DType::F32 => "w16",
            other => bail!("no compiled weight variant for {other} weights"),
        };
        let kv_prec = KvPrecision::from_dtype(cfg.precision.kv)?;
        let kv_key = kv_prec.graph_key();

        // Every (batch, context) graph the engine may need must exist.
        for &b in &runtime.manifest.decode_batches {
            for &t in &runtime.manifest.decode_t {
                if b <= cfg.max_batch {
                    let name = Manifest::decode_graph(wprec, kv_key, b, t);
                    runtime.graph(&name).with_context(|| {
                        format!("precision {} has no compiled variant", cfg.precision)
                    })?;
                }
            }
        }

        let pool = KvPool::new(
            kv_prec,
            m.n_layers,
            m.n_kv_heads,
            m.head_dim,
            cfg.kv_block_tokens,
            cfg.kv_pool_tokens,
        )?;

        let sampler = Sampler { temperature: cfg.temperature, top_k: cfg.top_k };
        Ok(Self {
            runtime,
            pool,
            scheduler: Scheduler::new(cfg.scheduler),
            sampler,
            rng: Rng::new(cfg.seed),
            wprec,
            kv_key,
            cfg,
            seqs: BTreeMap::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            next_id: 0,
            outputs: Vec::new(),
            stats: EngineStats::default(),
        })
    }

    /// Pre-compile the graphs this configuration uses.
    pub fn warmup(&self) -> Result<()> {
        let mut names = Vec::new();
        for &b in &self.runtime.manifest.decode_batches {
            for &t in &self.runtime.manifest.decode_t {
                if b <= self.cfg.max_batch {
                    names.push(Manifest::decode_graph(self.wprec, self.kv_key, b, t));
                }
            }
        }
        for &s in &self.runtime.manifest.prefill_chunks {
            names.push(Manifest::prefill_graph(self.wprec, self.kv_key, s));
        }
        self.runtime.warmup(&names)
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn model(&self) -> &crate::runtime::manifest::ManifestModel {
        &self.runtime.manifest.model
    }

    /// Submit a request; returns its id. Rejects requests that can never be
    /// scheduled (longer than the model context or the whole pool).
    pub fn submit(&mut self, req: Request) -> Result<u64> {
        let total = req.prompt.len() + req.max_new_tokens;
        let m = &self.runtime.manifest.model;
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if total > m.max_seq_len {
            bail!("request needs {total} tokens > context {}", m.max_seq_len);
        }
        if self.pool.blocks_for(total) > self.pool.total_blocks() {
            bail!("request needs more KV than the entire pool");
        }
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= m.vocab_size) {
            bail!("prompt token {t} outside vocab {}", m.vocab_size);
        }
        let id = self.next_id;
        self.next_id += 1;
        self.seqs.insert(id, SeqState::new(id, req, Instant::now()));
        self.waiting.push_back(id);
        Ok(id)
    }

    /// Whether any work remains.
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain finished outputs.
    pub fn take_outputs(&mut self) -> Vec<RequestOutput> {
        std::mem::take(&mut self.outputs)
    }

    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// One engine iteration.
    pub fn step(&mut self) -> Result<StepReport> {
        let admissible = self.head_admissible();
        let action = self.scheduler.next_action(
            self.waiting.len(),
            admissible,
            self.running.len(),
            self.cfg.max_batch,
        );
        match action {
            Action::Prefill => self.step_prefill(),
            Action::Decode => self.step_decode(),
            Action::Idle => {
                self.stats.idle_iters += 1;
                Ok(StepReport { action, emitted: vec![], finished: vec![] })
            }
        }
    }

    /// Run until all submitted requests complete; returns their outputs.
    pub fn run_to_completion(&mut self) -> Result<Vec<RequestOutput>> {
        let mut guard = 0usize;
        while self.has_work() {
            let r = self.step()?;
            if r.action == Action::Idle {
                guard += 1;
                if guard > 4 {
                    bail!(
                        "engine stalled: {} waiting, {} running, {} free blocks",
                        self.waiting.len(),
                        self.running.len(),
                        self.pool.free_blocks()
                    );
                }
            } else {
                guard = 0;
            }
        }
        Ok(self.take_outputs())
    }

    // ---- internals --------------------------------------------------------

    fn head_admissible(&self) -> bool {
        let Some(&id) = self.waiting.front() else { return false };
        let s = &self.seqs[&id];
        if s.handle.is_some() {
            return true; // already admitted, mid-prefill
        }
        // Conservative reservation: full prompt + generation budget.
        self.pool.can_reserve(s.prompt.len() + s.max_new_tokens)
    }

    /// Pick the compiled prefill bucket for `remaining` prompt tokens.
    fn prefill_bucket(&self, remaining: usize) -> usize {
        let chunks = &self.runtime.manifest.prefill_chunks;
        *chunks
            .iter()
            .filter(|&&c| c >= remaining.min(self.cfg.prefill_chunk))
            .min()
            .unwrap_or_else(|| chunks.iter().max().expect("no prefill chunks"))
    }

    /// Pick the compiled decode batch for `n` live sequences.
    fn decode_batch_size(&self, n: usize) -> Result<usize> {
        self.runtime
            .manifest
            .decode_batches
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .ok_or_else(|| anyhow!("no compiled decode batch >= {n}"))
    }

    /// Pick the compiled decode context bucket covering `need` tokens —
    /// short contexts avoid the full max_seq_len attention scan (§Perf).
    fn decode_t_bucket(&self, need: usize) -> Result<usize> {
        self.runtime
            .manifest
            .decode_t
            .iter()
            .copied()
            .filter(|&t| t >= need)
            .min()
            .ok_or_else(|| anyhow!("context {need} exceeds every compiled decode bucket"))
    }

    fn step_prefill(&mut self) -> Result<StepReport> {
        self.stats.prefill_iters += 1;
        let id = *self.waiting.front().expect("scheduler said Prefill");
        let m = self.runtime.manifest.model.clone();
        let t_pad = m.max_seq_len;
        let rb = self.pool.row_bytes();

        // Admit if new.
        {
            let s = self.seqs.get_mut(&id).unwrap();
            if s.handle.is_none() {
                s.handle = Some(self.pool.alloc_seq());
                s.phase = Phase::Prefilling;
            }
        }

        let (handle, pos, chunk_tokens, bucket, real) = {
            let s = &self.seqs[&id];
            let rem = s.remaining_prompt();
            let bucket = self.prefill_bucket(rem);
            let real = rem.min(bucket);
            let mut toks: Vec<i32> = s.prompt[s.prefill_pos..s.prefill_pos + real].to_vec();
            toks.resize(bucket, 0);
            (s.handle.unwrap(), s.prefill_pos, toks, bucket, real)
        };

        // Gather the (possibly empty) past context for this sequence.
        let kdim = m.n_layers * m.n_kv_heads * t_pad;
        let mut k_codes = vec![0u8; kdim * rb];
        let mut v_codes = vec![0u8; kdim * rb];
        let mut k_scales = vec![1f32; kdim];
        let mut v_scales = vec![1f32; kdim];
        self.pool.gather_batch(
            &[Some(handle)],
            t_pad,
            &mut k_codes,
            &mut k_scales,
            &mut v_codes,
            &mut v_scales,
        )?;

        let code_dt = self.code_dt();
        let cache_shape = vec![m.n_layers, 1, m.n_kv_heads, t_pad, rb / code_elem_size(code_dt)];
        let scale_shape = vec![m.n_layers, 1, m.n_kv_heads, t_pad];
        let graph = Manifest::prefill_graph(self.wprec, self.kv_key, bucket);
        let outputs = self.runtime.execute(
            &graph,
            &[
                HostTensor::from_i32(vec![bucket], &chunk_tokens)?,
                HostTensor::from_i32(vec![1], &[pos as i32])?,
                HostTensor::new(code_dt, cache_shape.clone(), k_codes)?,
                HostTensor::new(Dt::F32, scale_shape.clone(), f32s_to_bytes(&k_scales))?,
                HostTensor::new(code_dt, cache_shape, v_codes)?,
                HostTensor::new(Dt::F32, scale_shape, f32s_to_bytes(&v_scales))?,
            ],
        )?;
        let [logits, k_chunk, k_sc, v_chunk, v_sc] = take5(outputs)?;

        // Store the real tokens' KV.
        let k_sc = k_sc.as_f32()?;
        let v_sc = v_sc.as_f32()?;
        if let Err(e) = self.pool.append_chunk(
            handle, real, bucket, &k_chunk.data, &k_sc, &v_chunk.data, &v_sc,
        ) {
            return self.abort(id, e);
        }

        let mut emitted = vec![];
        let mut finished = vec![];
        {
            let s = self.seqs.get_mut(&id).unwrap();
            s.prefill_pos += real;
            self.stats.prompt_tokens += real;
            if s.remaining_prompt() == 0 {
                // Prompt done: sample the first token from the last real row.
                let lrow = logits.as_f32()?;
                let v = m.vocab_size;
                let row = &lrow[(real - 1) * v..real * v];
                let tok = self.sampler.sample(row, &mut self.rng);
                s.generated.push(tok);
                s.first_token = Some(Instant::now());
                s.phase = Phase::Decoding;
                emitted.push((id, tok));
                self.stats.tokens_generated += 1;
                self.waiting.pop_front();
                if let Some(reason) = s.should_finish() {
                    finished.push(id);
                    self.finish(id, reason);
                } else {
                    self.running.push(id);
                }
            }
        }
        Ok(StepReport { action: Action::Prefill, emitted, finished })
    }

    fn step_decode(&mut self) -> Result<StepReport> {
        self.stats.decode_iters += 1;
        let m = self.runtime.manifest.model.clone();
        let rb = self.pool.row_bytes();
        let ids: Vec<u64> = self.running.clone();
        let n = ids.len();
        assert!(n > 0, "scheduler said Decode with empty batch");
        let bsize = self.decode_batch_size(n)?;
        self.stats.padded_slots += bsize - n;

        let mut tokens = vec![0i32; bsize];
        let mut kv_len = vec![1i32; bsize];
        let mut handles: Vec<Option<SeqHandle>> = vec![None; bsize];
        let mut t_need = 2usize; // kv_len + 1 for the inserted token
        for (i, id) in ids.iter().enumerate() {
            let s = &self.seqs[id];
            tokens[i] = s.next_input_token();
            let len = self.pool.seq_len(s.handle.unwrap());
            kv_len[i] = len as i32;
            t_need = t_need.max(len + 1);
            handles[i] = s.handle;
        }
        let t_pad = self.decode_t_bucket(t_need)?;

        let kdim = m.n_layers * bsize * m.n_kv_heads * t_pad;
        let mut k_codes = vec![0u8; kdim * rb];
        let mut v_codes = vec![0u8; kdim * rb];
        let mut k_scales = vec![1f32; kdim];
        let mut v_scales = vec![1f32; kdim];
        self.pool.gather_batch(
            &handles, t_pad, &mut k_codes, &mut k_scales, &mut v_codes, &mut v_scales,
        )?;

        let code_dt = self.code_dt();
        let elem = code_elem_size(code_dt);
        let cache_shape = vec![m.n_layers, bsize, m.n_kv_heads, t_pad, rb / elem];
        let scale_shape = vec![m.n_layers, bsize, m.n_kv_heads, t_pad];
        let graph = Manifest::decode_graph(self.wprec, self.kv_key, bsize, t_pad);
        let outputs = self.runtime.execute(
            &graph,
            &[
                HostTensor::from_i32(vec![bsize], &tokens)?,
                HostTensor::from_i32(vec![bsize], &kv_len)?,
                HostTensor::new(code_dt, cache_shape.clone(), k_codes)?,
                HostTensor::new(Dt::F32, scale_shape.clone(), f32s_to_bytes(&k_scales))?,
                HostTensor::new(code_dt, cache_shape, v_codes)?,
                HostTensor::new(Dt::F32, scale_shape, f32s_to_bytes(&v_scales))?,
            ],
        )?;
        let [logits, k_new, k_sc, v_new, v_sc] = take5(outputs)?;
        let logits = logits.as_f32()?;
        let k_sc = k_sc.as_f32()?;
        let v_sc = v_sc.as_f32()?;

        // Append each live sequence's new KV codes ([L,B,Hkv,rb] layout).
        let mut emitted = vec![];
        let mut finished = vec![];
        for (i, id) in ids.iter().enumerate() {
            let handle = self.seqs[id].handle.unwrap();
            let per = m.n_kv_heads * rb;
            let mut kc = vec![0u8; m.n_layers * per];
            let mut vc = vec![0u8; m.n_layers * per];
            let mut ks = vec![0f32; m.n_layers * m.n_kv_heads];
            let mut vs = vec![0f32; m.n_layers * m.n_kv_heads];
            for l in 0..m.n_layers {
                let src = (l * bsize + i) * per;
                kc[l * per..(l + 1) * per].copy_from_slice(&k_new.data[src..src + per]);
                vc[l * per..(l + 1) * per].copy_from_slice(&v_new.data[src..src + per]);
                let ssrc = (l * bsize + i) * m.n_kv_heads;
                ks[l * m.n_kv_heads..(l + 1) * m.n_kv_heads]
                    .copy_from_slice(&k_sc[ssrc..ssrc + m.n_kv_heads]);
                vs[l * m.n_kv_heads..(l + 1) * m.n_kv_heads]
                    .copy_from_slice(&v_sc[ssrc..ssrc + m.n_kv_heads]);
            }
            if let Err(_e) = self.pool.append_token(handle, &kc, &ks, &vc, &vs) {
                // KV exhausted mid-flight (admission reserve should prevent
                // this); abort the sequence and keep the batch going.
                self.running.retain(|x| x != id);
                self.finish(*id, FinishReason::Aborted);
                self.stats.aborted += 1;
                finished.push(*id);
                continue;
            }

            let v = m.vocab_size;
            let tok = self.sampler.sample(&logits[i * v..(i + 1) * v], &mut self.rng);
            let s = self.seqs.get_mut(id).unwrap();
            s.generated.push(tok);
            emitted.push((*id, tok));
            self.stats.tokens_generated += 1;
            if let Some(reason) = s.should_finish() {
                self.running.retain(|x| x != id);
                self.finish(*id, reason);
                finished.push(*id);
            }
        }
        Ok(StepReport { action: Action::Decode, emitted, finished })
    }

    fn finish(&mut self, id: u64, reason: FinishReason) {
        let s = self.seqs.get_mut(&id).unwrap();
        if let Some(h) = s.handle.take() {
            self.pool.free_seq(h);
        }
        s.phase = Phase::Finished(reason);
        let now = Instant::now();
        self.outputs.push(RequestOutput {
            id,
            tokens: s.generated.clone(),
            finish: reason,
            ttft: s
                .first_token
                .map(|t| t.duration_since(s.submitted).as_secs_f64())
                .unwrap_or(f64::NAN),
            latency: now.duration_since(s.submitted).as_secs_f64(),
            prompt_len: s.prompt.len(),
        });
        self.seqs.remove(&id);
    }

    fn abort(&mut self, id: u64, err: anyhow::Error) -> Result<StepReport> {
        self.waiting.retain(|x| *x != id);
        self.running.retain(|x| *x != id);
        self.finish(id, FinishReason::Aborted);
        self.stats.aborted += 1;
        eprintln!("request {id} aborted: {err}");
        Ok(StepReport { action: Action::Prefill, emitted: vec![], finished: vec![id] })
    }

    fn code_dt(&self) -> Dt {
        match self.pool.precision() {
            KvPrecision::F32 => Dt::F32,
            KvPrecision::Int8 => Dt::I8,
            KvPrecision::Int4 => Dt::U8,
        }
    }
}

fn code_elem_size(dt: Dt) -> usize {
    dt.size()
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn take5(mut v: Vec<HostTensor>) -> Result<[HostTensor; 5]> {
    if v.len() != 5 {
        bail!("expected 5 outputs, got {}", v.len());
    }
    let e = v.remove(4);
    let d = v.remove(3);
    let c = v.remove(2);
    let b = v.remove(1);
    let a = v.remove(0);
    Ok([a, b, c, d, e])
}

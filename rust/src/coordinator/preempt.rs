//! Precision-aware preemption cost model (DESIGN.md §8).
//!
//! When the KV pool runs dry mid-flight the scheduler must pick a running
//! victim and a mechanism — **swap** (ship its quantized blocks to the
//! host store) or **recompute** (drop them and re-prefill on resume). Both
//! are lossless; they differ only in cost:
//!
//! * swap cost is *byte*-bound: codes (`resident blocks × block_tokens ×
//!   token_code_bytes`) plus the precision-independent f32 scale payload,
//!   paid twice (out + in) over the modeled PCIe link — the same bytes the
//!   engine charges to `sim_time_s`. `token_code_bytes` is `L × 2 × Hkv ×
//!   KvPrecision::row_bytes`, so the code term scales exactly with the KV
//!   precision — a kv4 victim's codes are ~4× cheaper to ship than the
//!   same victim's at kv16 (the paper's KV-format byte accounting; cf.
//!   KVmix's precision-driven memory policy);
//! * recompute cost is *token*-bound: re-prefilling the suffix the prefix
//!   index does **not** already hold. A victim whose tokens are fully
//!   prefix-cached recomputes for free (the blocks are still resident —
//!   resume just re-adopts them), so cached victims always prefer
//!   recompute.
//!
//! Pure functions, unit-tested in isolation; the engine feeds them live
//! pool/prefix state.

use crate::kvcache::swap::{disk_transfer_time_s, transfer_time_s};

/// How a preempted victim's KV is preserved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMechanism {
    /// Copy blocks to the host swap store; restore byte-exactly on resume.
    Swap,
    /// Release blocks; re-prefill the non-prefix-cached suffix on resume.
    Recompute,
    /// Nothing is evicted: the *whole pool* laddered down one per-layer
    /// precision rung in place, and this sequence restarted its generation
    /// at the narrower layout (determinism contract). Chosen by the engine
    /// *before* victim selection when the rung frees enough blocks — it
    /// never competes inside [`pick_victim`], so [`VictimCost::cost_of`]
    /// prices it as infinite.
    Ladder,
}

impl PreemptMechanism {
    /// Stable wire code for trace events
    /// ([`crate::trace::mechanism_name`] is the inverse).
    pub fn trace_code(self) -> u8 {
        match self {
            PreemptMechanism::Swap => 0,
            PreemptMechanism::Recompute => 1,
            PreemptMechanism::Ladder => 2,
        }
    }
}

/// Modeled per-token prefill cost used to price recompute, seconds. Tuned
/// to the gpusim tiny-model scale; the *ratio* against PCIe byte cost is
/// what drives mechanism choice, not the absolute number.
pub const RECOMPUTE_TOKEN_S: f64 = 4.0e-6;

/// Modeled on-device memory bandwidth used to price in-place transcodes,
/// bytes/s. A ladder rung reads every resident code row at the old width
/// and writes it at the new one — HBM traffic, never the host link, which
/// is why laddering undercuts swap by orders of magnitude per byte.
pub const HBM_BANDWIDTH_BPS: f64 = 2.0e12;

/// Cost estimate for one pool-wide precision-ladder rung (the in-place
/// alternative the engine prices *before* swap/recompute victim selection).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LadderCost {
    /// Bytes moved by the transcode walk (old row read + new row write,
    /// summed over every resident block's changed layers).
    pub transcode_bytes: usize,
    /// Free blocks the narrower layout yields from the same byte budget.
    pub gained_blocks: usize,
    /// Generated tokens dropped by decode restarts (the determinism
    /// contract re-runs generation at the final layout), re-decoded later.
    pub dropped_decode_tokens: usize,
    /// Transcode walk time at [`HBM_BANDWIDTH_BPS`], seconds.
    pub transcode_time_s: f64,
    /// Modeled re-decode time for the dropped tokens, seconds.
    pub redecode_time_s: f64,
}

impl LadderCost {
    pub fn estimate(
        transcode_bytes: usize,
        gained_blocks: usize,
        dropped_decode_tokens: usize,
    ) -> Self {
        Self {
            transcode_bytes,
            gained_blocks,
            dropped_decode_tokens,
            transcode_time_s: transcode_bytes as f64 / HBM_BANDWIDTH_BPS,
            redecode_time_s: dropped_decode_tokens as f64 * RECOMPUTE_TOKEN_S,
        }
    }

    /// Total modeled cost of taking this rung, seconds.
    pub fn time_s(&self) -> f64 {
        self.transcode_time_s + self.redecode_time_s
    }

    /// Whether the rung alone satisfies the allocation that triggered
    /// preemption — the ISSUE's "chosen before swap/recompute when it
    /// frees enough" rule.
    pub fn frees_enough(&self, needed_blocks: usize) -> bool {
        self.gained_blocks >= needed_blocks
    }
}

/// Preemption cost estimate for one candidate victim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VictimCost {
    /// Quantized code bytes priced for transfer — whole resident blocks
    /// (the ISSUE's `resident blocks × row_bytes` accounting, matching
    /// block-granular pinned-host staging), a conservative upper bound on
    /// the dense per-token payload the engine actually charges (they
    /// differ by at most one partial tail block). This is the
    /// precision-dependent term: exactly proportional to
    /// [`KvPrecision::row_bytes`](crate::kvcache::KvPrecision::row_bytes).
    pub swap_bytes: usize,
    /// Dequantization-scale payload shipping alongside the codes (one f32
    /// per (token, layer, K/V, head)) — precision-independent, so it
    /// dilutes but never inverts the `swap_bytes` precision scaling.
    pub scale_bytes: usize,
    /// Tokens that would re-prefill on resume (KV length minus the prefix
    /// the cache already holds).
    pub recompute_tokens: usize,
    /// Modeled swap round-trip (out + in) over the host link, seconds,
    /// priced on codes + scales at whole-block granularity.
    pub swap_time_s: f64,
    /// Modeled resume re-prefill time, seconds.
    pub recompute_time_s: f64,
}

impl VictimCost {
    /// Estimate costs for a victim with `resident_blocks` pool blocks of
    /// `block_tokens` tokens at `token_code_bytes` code bytes (`L × 2 ×
    /// Hkv × row_bytes` — the precision-dependent term) plus
    /// `token_scale_bytes` scale bytes per token slot, a live KV of
    /// `kv_len` tokens, of which the leading `cached_tokens` are already
    /// held by the prefix index.
    pub fn estimate(
        resident_blocks: usize,
        block_tokens: usize,
        token_code_bytes: usize,
        token_scale_bytes: usize,
        kv_len: usize,
        cached_tokens: usize,
    ) -> Self {
        let tokens = resident_blocks * block_tokens;
        let swap_bytes = tokens * token_code_bytes;
        let scale_bytes = tokens * token_scale_bytes;
        let recompute_tokens = kv_len.saturating_sub(cached_tokens.min(kv_len));
        Self {
            swap_bytes,
            scale_bytes,
            recompute_tokens,
            swap_time_s: 2.0 * transfer_time_s(swap_bytes + scale_bytes),
            recompute_time_s: recompute_tokens as f64 * RECOMPUTE_TOKEN_S,
        }
    }

    /// Re-price the swap round-trip for a disk-tier backend: on top of the
    /// two PCIe hops, the bytes cross the page-file link twice
    /// ([`disk_transfer_time_s`]). Recompute is unaffected, so a disk tier
    /// shifts the break-even toward recompute for short victims — exactly
    /// the behavior the slower-but-bigger tier should buy.
    pub fn with_disk_tier(mut self) -> Self {
        self.swap_time_s += 2.0 * disk_transfer_time_s(self.swap_bytes + self.scale_bytes);
        self
    }

    /// The cheaper mechanism for this victim. Ties go to recompute — it
    /// leaves the swap budget untouched.
    pub fn preferred(&self) -> PreemptMechanism {
        if self.recompute_time_s <= self.swap_time_s {
            PreemptMechanism::Recompute
        } else {
            PreemptMechanism::Swap
        }
    }

    /// The cost this victim pays under the given mechanism, seconds.
    /// `Ladder` is not a per-victim mechanism (no victim pays for it), so
    /// it prices as infinite and can never win victim selection.
    pub fn cost_of(&self, mech: PreemptMechanism) -> f64 {
        match mech {
            PreemptMechanism::Swap => self.swap_time_s,
            PreemptMechanism::Recompute => self.recompute_time_s,
            PreemptMechanism::Ladder => f64::INFINITY,
        }
    }
}

/// Pick the cheapest victim from `(id, cost)` candidates under `mech`
/// (`None` = each victim's own preferred mechanism). Ties break toward the
/// **highest id** — the youngest request, vLLM-style, so long-running work
/// is disturbed last — and the choice is deterministic either way. Returns
/// the winning id and the mechanism it should use.
pub fn pick_victim(
    candidates: &[(u64, VictimCost)],
    mech: Option<PreemptMechanism>,
) -> Option<(u64, PreemptMechanism)> {
    candidates
        .iter()
        .map(|&(id, c)| {
            let m = mech.unwrap_or_else(|| c.preferred());
            (id, m, c.cost_of(m))
        })
        .min_by(|a, b| a.2.total_cmp(&b.2).then(b.0.cmp(&a.0)))
        .map(|(id, m, _)| (id, m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvPrecision;

    /// `token_code_bytes` for a 2-layer, 2-head pool at `prec`/`head_dim`.
    fn tcb(prec: KvPrecision, head_dim: usize) -> usize {
        2 * 2 * 2 * prec.row_bytes(head_dim)
    }

    /// Matching per-token scale bytes (f32 per (layer, K/V, head)) —
    /// identical at every precision.
    const TSB: usize = 2 * 2 * 2 * 4;

    #[test]
    fn swap_bytes_scale_exactly_with_kv_precision() {
        // Same victim geometry at kv16 / kv8 / kv4: byte estimates follow
        // row_bytes exactly — 4× between f32 and int8, 2× int8 vs int4.
        let c16 = VictimCost::estimate(3, 16, tcb(KvPrecision::F32, 8), TSB, 40, 0);
        let c8 = VictimCost::estimate(3, 16, tcb(KvPrecision::Int8, 8), TSB, 40, 0);
        let c4 = VictimCost::estimate(3, 16, tcb(KvPrecision::Int4, 8), TSB, 40, 0);
        assert_eq!(c16.swap_bytes, 4 * c8.swap_bytes);
        assert_eq!(c8.swap_bytes, 2 * c4.swap_bytes);
        assert!(c16.swap_time_s > c8.swap_time_s && c8.swap_time_s > c4.swap_time_s);
        // Recompute cost is precision-independent.
        assert_eq!(c16.recompute_tokens, c8.recompute_tokens);
        assert_eq!(c16.recompute_time_s, c4.recompute_time_s);
    }

    #[test]
    fn int4_odd_head_dim_rounds_up_in_the_estimate() {
        // head_dim 7 packs to 4 bytes/row, not 3.5 (the PR 2 fix): the
        // byte estimate must price the rounded row, so head_dim 7 and 8
        // cost the same at int4.
        let c7 = VictimCost::estimate(2, 16, tcb(KvPrecision::Int4, 7), TSB, 30, 0);
        let c8 = VictimCost::estimate(2, 16, tcb(KvPrecision::Int4, 8), TSB, 30, 0);
        assert_eq!(c7.swap_bytes, c8.swap_bytes);
        assert_eq!(c7.swap_bytes, 2 * 16 * 2 * 2 * 2 * 4);
    }

    #[test]
    fn fully_prefix_cached_victims_always_prefer_recompute() {
        // Everything the victim holds is in the prefix index: recompute is
        // free (re-adopt on resume), so it must win at every precision —
        // even kv4, where swap is cheapest.
        for prec in [KvPrecision::F32, KvPrecision::Int8, KvPrecision::Int4] {
            let c = VictimCost::estimate(4, 16, tcb(prec, 8), TSB, 64, 64);
            assert_eq!(c.recompute_tokens, 0);
            assert_eq!(c.recompute_time_s, 0.0);
            assert_eq!(c.preferred(), PreemptMechanism::Recompute, "{prec:?}");
        }
    }

    #[test]
    fn long_uncached_victims_prefer_swap() {
        // A long victim with no cached prefix: re-prefilling thousands of
        // tokens dwarfs shipping a few KB of int4 codes.
        let c = VictimCost::estimate(128, 16, tcb(KvPrecision::Int4, 8), TSB, 2048, 0);
        assert_eq!(c.recompute_tokens, 2048);
        assert_eq!(c.preferred(), PreemptMechanism::Swap);
    }

    #[test]
    fn cached_tokens_shrink_recompute_not_swap() {
        let none = VictimCost::estimate(4, 16, tcb(KvPrecision::Int8, 8), TSB, 60, 0);
        let half = VictimCost::estimate(4, 16, tcb(KvPrecision::Int8, 8), TSB, 60, 32);
        assert_eq!(half.recompute_tokens, 28);
        assert!(half.recompute_time_s < none.recompute_time_s);
        assert_eq!(half.swap_bytes, none.swap_bytes, "swap ships all resident blocks");
        // Over-reported cache coverage saturates at kv_len.
        let over = VictimCost::estimate(4, 16, tcb(KvPrecision::Int8, 8), TSB, 60, 999);
        assert_eq!(over.recompute_tokens, 0);
    }

    #[test]
    fn pick_victim_is_cheapest_then_youngest() {
        let cheap = VictimCost::estimate(1, 16, tcb(KvPrecision::Int8, 8), TSB, 16, 0);
        let dear = VictimCost::estimate(8, 16, tcb(KvPrecision::Int8, 8), TSB, 128, 0);
        let picked = pick_victim(
            &[(1, dear), (2, cheap), (3, dear)],
            Some(PreemptMechanism::Recompute),
        );
        assert_eq!(picked, Some((2, PreemptMechanism::Recompute)));
        // Equal costs → highest id (youngest) wins.
        let tie = pick_victim(&[(5, cheap), (9, cheap)], Some(PreemptMechanism::Swap));
        assert_eq!(tie, Some((9, PreemptMechanism::Swap)));
        // Adaptive mode picks each victim's preferred mechanism.
        let cached = VictimCost::estimate(4, 16, tcb(KvPrecision::Int8, 8), TSB, 64, 64);
        let adaptive = pick_victim(&[(1, dear), (2, cached)], None);
        assert_eq!(adaptive, Some((2, PreemptMechanism::Recompute)));
        assert_eq!(pick_victim(&[], None), None);
    }

    #[test]
    fn disk_tier_adds_a_round_trip_and_spares_recompute() {
        let base = VictimCost::estimate(4, 16, tcb(KvPrecision::Int8, 8), TSB, 60, 0);
        let disk = base.with_disk_tier();
        let extra = 2.0 * crate::kvcache::swap::disk_transfer_time_s(
            base.swap_bytes + base.scale_bytes,
        );
        assert!((disk.swap_time_s - base.swap_time_s - extra).abs() < 1e-12);
        assert_eq!(disk.recompute_time_s, base.recompute_time_s);
        assert_eq!(disk.swap_bytes, base.swap_bytes);
        // A short victim that barely preferred swap flips to recompute
        // once the disk term lands.
        let short = VictimCost::estimate(1, 16, tcb(KvPrecision::F32, 8), TSB, 22, 0);
        assert_eq!(short.preferred(), PreemptMechanism::Swap);
        assert_eq!(short.with_disk_tier().preferred(), PreemptMechanism::Recompute);
    }

    #[test]
    fn ladder_cost_prices_hbm_transcode_plus_redecode() {
        let c = LadderCost::estimate(2_000_000, 8, 100);
        assert!((c.transcode_time_s - 2.0e6 / HBM_BANDWIDTH_BPS).abs() < 1e-12);
        assert!((c.redecode_time_s - 100.0 * RECOMPUTE_TOKEN_S).abs() < 1e-12);
        assert!((c.time_s() - (c.transcode_time_s + c.redecode_time_s)).abs() < 1e-15);
        assert!(c.frees_enough(8) && !c.frees_enough(9));

        // The headline economics: transcoding a victim's bytes over HBM is
        // orders of magnitude cheaper than shipping the same bytes over the
        // host link twice.
        let v = VictimCost::estimate(8, 16, 2 * 2 * 2 * 8, 2 * 2 * 2 * 4, 128, 0);
        let l = LadderCost::estimate(v.swap_bytes + v.scale_bytes, 8, 0);
        assert!(l.transcode_time_s * 100.0 < v.swap_time_s);
    }

    #[test]
    fn ladder_mechanism_never_wins_victim_selection() {
        let c = VictimCost::estimate(2, 16, 2 * 2 * 2 * 8, TSB, 32, 0);
        assert_eq!(c.cost_of(PreemptMechanism::Ladder), f64::INFINITY);
        let picked = pick_victim(&[(1, c)], Some(PreemptMechanism::Ladder));
        // Forced ladder "mechanism" still resolves to a victim entry, but
        // the engine only reaches pick_victim after deciding NOT to ladder.
        assert_eq!(picked, Some((1, PreemptMechanism::Ladder)));
        assert_ne!(c.preferred(), PreemptMechanism::Ladder);
    }
}

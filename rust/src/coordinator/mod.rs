//! Layer-3 coordinator: the serving engine.
//!
//! Request admission, continuous batching (iteration-level scheduling with
//! chunked prefill), paged quantized KV management, sampling, and lifecycle
//! tracking. This is the Rust process that owns the request path; the
//! AOT-compiled graphs (Layer 2 + Layer 1) are invoked through [`crate::runtime`].

pub mod engine;
pub mod preempt;
pub mod request;
pub mod sampler;
pub mod scheduler;

pub use engine::{Engine, EngineStats, MigrationStats, PreemptStats, ResumeArtifact, StepReport};
pub use preempt::{PreemptMechanism, VictimCost};
pub use request::{FinishReason, Phase, Request, RequestOutput};
pub use sampler::Sampler;
pub use scheduler::{Action, Scheduler};

//! Token sampling: greedy and temperature/top-k.

use crate::util::rng::Rng;

/// Sampling configuration.
#[derive(Debug, Clone, Copy)]
pub struct Sampler {
    /// 0.0 = greedy argmax.
    pub temperature: f32,
    /// Keep only the k highest logits (0 = all).
    pub top_k: usize,
}

impl Sampler {
    pub fn greedy() -> Self {
        Self { temperature: 0.0, top_k: 0 }
    }

    /// Sample a token id from a logits row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        assert!(!logits.is_empty());
        if self.temperature <= 0.0 {
            return argmax(logits) as i32;
        }
        // Top-k filter.
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        if self.top_k > 0 && self.top_k < logits.len() {
            idx.sort_unstable_by(|&a, &b| logits[b].total_cmp(&logits[a]));
            idx.truncate(self.top_k);
        }
        // Softmax over the kept set at the given temperature.
        let m = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f64> =
            idx.iter().map(|&i| (((logits[i] - m) / self.temperature) as f64).exp()).collect();
        let total: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= total;
        }
        let mut u = rng.next_f64();
        for (i, p) in idx.iter().zip(&probs) {
            if u < *p {
                return *i as i32;
            }
            u -= p;
        }
        *idx.last().unwrap() as i32
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(1);
        let s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 2.0, -1.0], &mut rng), 1);
        assert_eq!(s.sample(&[5.0, 2.0], &mut rng), 0);
    }

    #[test]
    fn greedy_ties_take_first() {
        let mut rng = Rng::new(1);
        assert_eq!(Sampler::greedy().sample(&[1.0, 1.0, 1.0], &mut rng), 0);
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Rng::new(2);
        let s = Sampler { temperature: 1.0, top_k: 2 };
        let logits = [10.0, 9.0, -50.0, -50.0];
        for _ in 0..200 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn temperature_spreads_distribution() {
        let mut rng = Rng::new(3);
        let hot = Sampler { temperature: 5.0, top_k: 0 };
        let logits = [1.0, 0.0, 0.0, 0.0];
        let n = 2000;
        let non_argmax = (0..n)
            .filter(|_| hot.sample(&logits, &mut rng) != 0)
            .count();
        // At T=5 the argmax advantage is tiny; roughly 3/4 go elsewhere.
        assert!(non_argmax > n / 2, "{non_argmax}/{n}");
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let s = Sampler { temperature: 0.8, top_k: 4 };
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.3).sin()).collect();
        let run = |seed| {
            let mut rng = Rng::new(seed);
            (0..32).map(|_| s.sample(&logits, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}

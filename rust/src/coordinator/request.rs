//! Request and output types for the serving engine.

use std::time::Instant;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    /// Prompt token ids (tokenization is out of scope — the synthetic
    /// workloads speak token ids directly).
    pub prompt: Vec<i32>,
    /// Maximum number of tokens to generate.
    pub max_new_tokens: usize,
    /// Optional stop token: generation ends early when sampled.
    pub stop_token: Option<i32>,
}

impl Request {
    pub fn new(prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Self { prompt, max_new_tokens, stop_token: None }
    }
}

/// Why a sequence finished.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Sampled the stop token.
    Stop,
    /// Evicted: the KV pool could not hold it. With
    /// `PreemptionMode::Abort` this is the overload escape hatch (the
    /// partial generation is still returned, with
    /// [`RequestOutput::abort_reason`] saying why); with swap/recompute
    /// preemption it should never happen mid-decode.
    Aborted,
}

/// Lifecycle phase of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the admission queue (no KV allocated yet).
    Waiting,
    /// Admitted; prompt chunks still running through prefill.
    Prefilling,
    /// Generating tokens in the decode batch.
    Decoding,
    /// Done; output available.
    Finished(FinishReason),
}

/// Completed output for a request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token, seconds.
    pub ttft: f64,
    /// Total request latency (submit → finish), seconds.
    pub latency: f64,
    /// TTFT on the engine's **modeled** device clock (`EngineStats::
    /// sim_time_s` advanced between submit and first token) — NaN when no
    /// token was ever emitted. Wall clock on the sim backend measures
    /// coordinator overhead; this is the deterministic serving-latency
    /// number the cluster bench compares policies on.
    pub ttft_sim: f64,
    /// Submit → finish on the modeled device clock.
    pub latency_sim: f64,
    pub prompt_len: usize,
    /// Prompt tokens served from the prefix cache (prefill skipped); 0
    /// when the cache is disabled or nothing matched.
    pub prefix_hit_tokens: usize,
    /// Times this request was preempted under KV pressure (swap or
    /// recompute; 0 on an unpressured run).
    pub preempt_count: usize,
    /// Pool blocks restored from the host swap store across all resumes.
    pub swapped_in_blocks: usize,
    /// Pool-wide precision-ladder events this request lived through while
    /// resident (each one restarted its generation at the narrower layout;
    /// 0 on an unpressured run).
    pub ladder_count: usize,
    /// The per-layer KV layout the pool held when this request finished —
    /// the *final* precision assignment the determinism contract is stated
    /// against (e.g. `kv16` or `l0:kv16,l1:kv8`).
    pub final_kv_layout: String,
    /// Why the request aborted (`finish == Aborted` only): the structured
    /// detail behind the opaque finish reason.
    pub abort_reason: Option<String>,
}

impl RequestOutput {
    /// The output fabricated for a request the engine refused at submit
    /// time (malformed for the model: empty prompt, out-of-vocab token,
    /// over-context). No engine id was ever assigned (`u64::MAX`), nothing
    /// ran, and the reason travels in `abort_reason`.
    pub fn rejected(reason: String) -> Self {
        Self {
            id: u64::MAX,
            tokens: vec![],
            finish: FinishReason::Aborted,
            ttft: f64::NAN,
            latency: 0.0,
            ttft_sim: f64::NAN,
            latency_sim: 0.0,
            prompt_len: 0,
            prefix_hit_tokens: 0,
            preempt_count: 0,
            swapped_in_blocks: 0,
            ladder_count: 0,
            final_kv_layout: String::new(),
            abort_reason: Some(reason),
        }
    }
}

/// Internal per-sequence engine state.
#[derive(Debug)]
pub(crate) struct SeqState {
    /// Request id (carried for diagnostics/logging).
    #[allow(dead_code)]
    pub id: u64,
    pub prompt: Vec<i32>,
    pub generated: Vec<i32>,
    pub max_new_tokens: usize,
    pub stop_token: Option<i32>,
    pub phase: Phase,
    /// The token stream prefill must make resident before decoding can
    /// (re)start. Equals `prompt` for fresh sequences; after a
    /// recompute-preemption it grows to `prompt ++ generated[..g-1]` — the
    /// generated-so-far suffix minus the last token, which is the next
    /// decode input, not cache content.
    pub seq_tokens: Vec<i32>,
    /// Tokens of `seq_tokens` prefilled so far (starts at the prefix-cache
    /// hit length — matched tokens are already resident and never re-run).
    pub prefill_pos: usize,
    /// Prompt tokens adopted from the prefix cache at admission.
    pub prefix_hit_tokens: usize,
    /// Full prompt blocks already registered in the prefix index (skips
    /// re-walking the chain when a chunk completes no new full block).
    pub indexed_blocks: usize,
    pub handle: Option<crate::kvcache::SeqHandle>,
    /// True while this request's KV lives in the host swap store; resume
    /// restores it instead of prefilling.
    pub swapped: bool,
    /// A layout-tagged KV snapshot shipped in from another replica
    /// (disaggregated prefill → decode migration), pending import. Like
    /// `swapped`, admission restores it instead of prefilling — but the
    /// payload travels with the sequence, not through the swap store,
    /// so migration never perturbs the swap accounting.
    pub migrate_snapshot: Option<crate::kvcache::SeqSnapshot>,
    /// Export this sequence's KV at finish (prefill-tier contract: the
    /// snapshot plus the first sampled token migrate to a decode replica).
    pub export_on_finish: bool,
    /// Times preempted (reported in [`RequestOutput::preempt_count`]).
    pub preempt_count: usize,
    /// Blocks restored from the swap store (cumulative).
    pub swapped_in_blocks: usize,
    /// Pool-wide ladder events survived while resident (cumulative).
    pub ladder_count: usize,
    /// Structured detail for an upcoming `FinishReason::Aborted` finish
    /// (set just before `Engine::finish`, moved into the output).
    pub abort_reason: Option<String>,
    pub submitted: Instant,
    pub first_token: Option<Instant>,
    /// `EngineStats::sim_time_s` when this request was submitted.
    pub submitted_sim_s: f64,
    /// Modeled clock at first-token emission (None until then).
    pub first_token_sim_s: Option<f64>,
}

impl SeqState {
    pub fn new(id: u64, req: Request, now: Instant) -> Self {
        Self {
            id,
            seq_tokens: req.prompt.clone(),
            prompt: req.prompt,
            generated: Vec::new(),
            max_new_tokens: req.max_new_tokens,
            stop_token: req.stop_token,
            phase: Phase::Waiting,
            prefill_pos: 0,
            prefix_hit_tokens: 0,
            indexed_blocks: 0,
            handle: None,
            swapped: false,
            migrate_snapshot: None,
            export_on_finish: false,
            preempt_count: 0,
            swapped_in_blocks: 0,
            ladder_count: 0,
            abort_reason: None,
            submitted: now,
            first_token: None,
            submitted_sim_s: 0.0,
            first_token_sim_s: None,
        }
    }

    /// The token to feed the next decode step (last generated).
    pub fn next_input_token(&self) -> i32 {
        *self.generated.last().expect("decode before first token")
    }

    pub fn remaining_prompt(&self) -> usize {
        self.seq_tokens.len() - self.prefill_pos
    }

    /// Has generation started? (Resumed prefills must not re-sample a
    /// first token when the final chunk completes.)
    pub fn decoding_started(&self) -> bool {
        !self.generated.is_empty()
    }

    /// The token stream currently resident in the KV cache for a decoding
    /// sequence: the prompt plus all generated tokens except the last —
    /// which is the pending decode input, not cache content. This is the
    /// single definition both the preemption cost model (pricing what a
    /// recompute would re-run) and [`SeqState::rebuild_seq_tokens`] use.
    pub fn resident_tokens(&self) -> Vec<i32> {
        let mut toks = self.prompt.clone();
        if self.generated.len() > 1 {
            toks.extend(&self.generated[..self.generated.len() - 1]);
        }
        toks
    }

    /// Rebuild `seq_tokens` to cover everything the KV cache must hold
    /// right now. Called when a victim is released for recompute, so a
    /// later re-prefill regenerates the exact pre-preemption contents.
    pub fn rebuild_seq_tokens(&mut self) {
        self.seq_tokens = self.resident_tokens();
    }

    pub fn should_finish(&self) -> Option<FinishReason> {
        if let (Some(stop), Some(&last)) = (self.stop_token, self.generated.last()) {
            if last == stop {
                return Some(FinishReason::Stop);
            }
        }
        (self.generated.len() >= self.max_new_tokens).then_some(FinishReason::Length)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finish_conditions() {
        let mut s = SeqState::new(1, Request::new(vec![1, 2], 3), Instant::now());
        assert!(s.should_finish().is_none());
        s.generated = vec![5, 6, 7];
        assert_eq!(s.should_finish(), Some(FinishReason::Length));

        let mut s = SeqState::new(2, Request { prompt: vec![1], max_new_tokens: 10,
                                               stop_token: Some(0) }, Instant::now());
        s.generated = vec![4, 0];
        assert_eq!(s.should_finish(), Some(FinishReason::Stop));
    }

    #[test]
    fn remaining_prompt_tracks_progress() {
        let mut s = SeqState::new(1, Request::new(vec![1; 100], 3), Instant::now());
        assert_eq!(s.remaining_prompt(), 100);
        s.prefill_pos = 64;
        assert_eq!(s.remaining_prompt(), 36);
    }

    #[test]
    fn rebuild_seq_tokens_covers_prompt_plus_generated_prefix() {
        let mut s = SeqState::new(1, Request::new(vec![1, 2, 3], 8), Instant::now());
        assert_eq!(s.seq_tokens, vec![1, 2, 3], "fresh: just the prompt");
        assert!(!s.decoding_started());

        // After 3 generated tokens the cache holds prompt + first 2: the
        // last token is the pending decode input.
        s.generated = vec![10, 11, 12];
        s.rebuild_seq_tokens();
        assert_eq!(s.seq_tokens, vec![1, 2, 3, 10, 11]);
        assert!(s.decoding_started());
        assert_eq!(s.next_input_token(), 12);

        // One generated token: the cache holds only the prompt.
        s.generated = vec![10];
        s.rebuild_seq_tokens();
        assert_eq!(s.seq_tokens, vec![1, 2, 3]);
    }
}

//! Iteration-level scheduling decisions (pure logic, unit-testable).
//!
//! The engine asks the scheduler what to run each iteration. `Continuous`
//! is vLLM/Orca-style continuous batching with prefill priority (admit new
//! work as soon as batch + KV budget allow — this is what keeps TTFT low in
//! the paper's online-serving comparisons). `Static` waits for a full batch
//! and drains it — the ablation baseline (`ablate_scheduler`).

use crate::config::engine::SchedulerPolicy;

/// What the engine should run this iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Run one prefill chunk for the head-of-queue request.
    Prefill,
    /// Run one decode step over the running batch.
    Decode,
    /// The decode batch cannot grow its KV: preempt `victim` (the
    /// cost-model choice the engine supplied), then decode the survivors
    /// **in the same iteration** — re-evaluating first would let admission
    /// steal the freed blocks and livelock the victim in a
    /// preempt/readmit cycle.
    Preempt { victim: u64 },
    /// A swap-preempted sequence was restored from the host store instead
    /// of prefilling. Appears only in `StepReport` — the scheduler itself
    /// emits `Prefill` for the head-of-queue and the engine discovers the
    /// resume shape; it never returns this variant.
    SwapIn,
    /// Nothing runnable.
    Idle,
}

/// Scheduler state (only `Static` needs any).
#[derive(Debug)]
pub struct Scheduler {
    policy: SchedulerPolicy,
    /// Static mode: true while draining the admitted batch.
    draining: bool,
}

impl Scheduler {
    pub fn new(policy: SchedulerPolicy) -> Self {
        Self { policy, draining: false }
    }

    pub fn policy(&self) -> SchedulerPolicy {
        self.policy
    }

    /// Decide the next action.
    ///
    /// * `waiting` — queued requests not yet admitted (or mid-prefill —
    ///   prefill continues until the prompt is fully processed).
    /// * `admissible` — whether the head-of-queue request fits the KV
    ///   budget. The engine computes this prefix-cache-aware: tokens whose
    ///   blocks are already resident in the prefix index cost nothing, and
    ///   unreferenced cached blocks count as free (they evict on demand),
    ///   so shared-prefix requests admit earlier than their raw footprint
    ///   suggests.
    /// * `running` — sequences currently decoding.
    /// * `max_batch` — decode batch capacity.
    /// * `preempt_victim` — `Some(id)` when the engine determined the next
    ///   decode step cannot fit the KV pool even after cache eviction, and
    ///   the precision-aware cost model picked `id` as the cheapest victim
    ///   ([`crate::coordinator::preempt`]). The scheduler turns what would
    ///   have been `Decode` into `Preempt { victim }`; `None` (always, in
    ///   abort mode, or with < 2 running) leaves decode to the legacy
    ///   abort-on-exhaustion path.
    pub fn next_action(
        &mut self,
        waiting: usize,
        admissible: bool,
        running: usize,
        max_batch: usize,
        preempt_victim: Option<u64>,
    ) -> Action {
        let decode = || match preempt_victim {
            Some(victim) => Action::Preempt { victim },
            None => Action::Decode,
        };
        match self.policy {
            SchedulerPolicy::Continuous => {
                if waiting > 0 && admissible && running < max_batch {
                    Action::Prefill
                } else if running > 0 {
                    decode()
                } else {
                    // Includes waiting > 0 with nothing running and nothing
                    // admissible. That combination can only be transient:
                    // `Engine::submit` rejects (FinishReason::Aborted) any
                    // request whose prompt + generation budget exceeds the
                    // whole pool, so a queued head always becomes admissible
                    // once in-flight sequences drain. Idle here is a canary
                    // the engine turns into a hard "stalled" error if it
                    // ever persists.
                    Action::Idle
                }
            }
            SchedulerPolicy::Static => {
                if self.draining {
                    if running > 0 {
                        return decode();
                    }
                    self.draining = false;
                }
                if waiting > 0 && admissible && running < max_batch {
                    Action::Prefill
                } else if running > 0 {
                    self.draining = true;
                    decode()
                } else {
                    Action::Idle
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuous_prefers_prefill() {
        let mut s = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(s.next_action(2, true, 3, 8, None), Action::Prefill);
        assert_eq!(s.next_action(0, true, 3, 8, None), Action::Decode);
        assert_eq!(s.next_action(0, true, 0, 8, None), Action::Idle);
    }

    #[test]
    fn continuous_decodes_when_batch_full() {
        let mut s = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(s.next_action(5, true, 8, 8, None), Action::Decode);
    }

    #[test]
    fn continuous_decodes_when_kv_tight() {
        let mut s = Scheduler::new(SchedulerPolicy::Continuous);
        // Not admissible → keep decoding to free KV.
        assert_eq!(s.next_action(5, false, 4, 8, None), Action::Decode);
        // Nothing running and nothing fits → stall, surfaced as Idle.
        assert_eq!(s.next_action(5, false, 0, 8, None), Action::Idle);
    }

    #[test]
    fn static_fills_then_drains() {
        let mut s = Scheduler::new(SchedulerPolicy::Static);
        // Admit until the batch is full…
        assert_eq!(s.next_action(4, true, 0, 2, None), Action::Prefill);
        assert_eq!(s.next_action(3, true, 1, 2, None), Action::Prefill);
        // …then drain without admitting.
        assert_eq!(s.next_action(2, true, 2, 2, None), Action::Decode);
        assert_eq!(s.next_action(2, true, 2, 2, None), Action::Decode);
        assert_eq!(s.next_action(2, true, 1, 2, None), Action::Decode);
        // Batch drained → back to admission.
        assert_eq!(s.next_action(2, true, 0, 2, None), Action::Prefill);
    }

    #[test]
    fn full_batch_with_admissible_waiting_work_decodes() {
        // running == max_batch: admissible waiting work must NOT preempt —
        // both policies keep decoding until a slot frees.
        let mut c = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(c.next_action(3, true, 8, 8, None), Action::Decode);
        let mut s = Scheduler::new(SchedulerPolicy::Static);
        assert_eq!(s.next_action(3, true, 8, 8, None), Action::Decode);
        // …and once a slot frees, Continuous admits immediately while
        // Static finishes its drain first.
        assert_eq!(c.next_action(3, true, 7, 8, None), Action::Prefill);
        assert_eq!(s.next_action(3, true, 7, 8, None), Action::Decode);
    }

    #[test]
    fn static_drain_reentry() {
        // After a drain fully empties, Static must re-enter admission —
        // and a second drain cycle must behave identically (the `draining`
        // flag resets).
        let mut s = Scheduler::new(SchedulerPolicy::Static);
        for _cycle in 0..2 {
            assert_eq!(s.next_action(2, true, 0, 2, None), Action::Prefill);
            assert_eq!(s.next_action(1, true, 1, 2, None), Action::Prefill);
            assert_eq!(s.next_action(0, true, 2, 2, None), Action::Decode);
            assert_eq!(s.next_action(0, true, 1, 2, None), Action::Decode);
            // Batch empty → drain ends; with an empty queue this is Idle,
            // not a stuck drain state.
            assert_eq!(s.next_action(0, true, 0, 2, None), Action::Idle);
        }
        // Drain interrupted by new admissible work after emptying: admit.
        assert_eq!(s.next_action(5, true, 0, 2, None), Action::Prefill);
    }

    #[test]
    fn idle_when_nothing_admissible_and_nothing_running() {
        // The former deadlock shape: waiting work that can't be admitted
        // with an empty batch. Submit-time rejection guarantees this is
        // transient; the scheduler reports Idle either way.
        let mut c = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(c.next_action(3, false, 0, 8, None), Action::Idle);
        let mut s = Scheduler::new(SchedulerPolicy::Static);
        assert_eq!(s.next_action(3, false, 0, 8, None), Action::Idle);
    }

    #[test]
    fn preempt_replaces_decode_when_kv_blocked() {
        // A blocked decode with a cost-model victim becomes Preempt — in
        // both policies, including mid-drain for Static.
        let mut c = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(c.next_action(0, true, 3, 8, Some(7)), Action::Preempt { victim: 7 });
        // Queue present but inadmissible: still preempt rather than decode.
        assert_eq!(c.next_action(2, false, 3, 8, Some(9)), Action::Preempt { victim: 9 });

        let mut s = Scheduler::new(SchedulerPolicy::Static);
        assert_eq!(s.next_action(0, true, 2, 2, Some(4)), Action::Preempt { victim: 4 });
        // Now draining: the blocked decode mid-drain also preempts.
        assert_eq!(s.next_action(0, true, 2, 2, Some(5)), Action::Preempt { victim: 5 });
    }

    #[test]
    fn preempt_never_fires_without_a_victim_or_ahead_of_prefill() {
        // No victim supplied (abort mode / sole runner) → plain Decode.
        let mut c = Scheduler::new(SchedulerPolicy::Continuous);
        assert_eq!(c.next_action(0, true, 3, 8, None), Action::Decode);
        // Admission still has priority in Continuous: a victim is only
        // consulted on the decode branch.
        assert_eq!(c.next_action(2, true, 3, 8, Some(1)), Action::Prefill);
        // Nothing running: a stale victim id cannot conjure a Preempt.
        assert_eq!(c.next_action(0, true, 0, 8, Some(1)), Action::Idle);
    }

    #[test]
    fn static_drains_partial_batch_when_queue_empties() {
        let mut s = Scheduler::new(SchedulerPolicy::Static);
        assert_eq!(s.next_action(1, true, 0, 4, None), Action::Prefill);
        // Queue empty with one running: drain it.
        assert_eq!(s.next_action(0, true, 1, 4, None), Action::Decode);
        assert_eq!(s.next_action(0, true, 0, 4, None), Action::Idle);
    }
}

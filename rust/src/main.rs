//! `turbomind` CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve       start the JSON-lines TCP server on the real engine
//!   run         drive a deterministic offline fleet run (flight-recorder driver)
//!   bench       regenerate a paper figure/table (or `all`)
//!   pack        run §4.1 hardware-aware weight packing on a demo matrix
//!   info        list artifacts, models, and device profiles
//!
//! Examples:
//!   turbomind serve --addr 127.0.0.1:7181 --precision W4A16KV8
//!   turbomind serve --backend pjrt --artifacts artifacts   (needs --features pjrt)
//!   turbomind run --replicas 2 --requests 24 --trace-out trace.json
//!   turbomind bench fig13
//!   turbomind pack --k 256 --n 4096

use anyhow::{bail, ensure, Result};
use turbomind::bench;
use turbomind::cluster::{self, Cluster, ClusterConfig, DisaggConfig, ReplicaSpec, RouterPolicy};
use turbomind::config::{
    BackendKind, DeviceProfile, EngineConfig, LadderPolicy, PrecisionFormat, PreemptionMode,
};
use turbomind::coordinator::{Engine, Request};
use turbomind::quant::{pack_weights_hw_aware, GroupwiseQuant, QuantizedMatrix};
use turbomind::quant::access::analyze_global;
use turbomind::quant::packing::naive_fragment_access;
use turbomind::server;
use turbomind::trace::{self, EventKind};
use turbomind::util::args::Args;
use turbomind::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&["help", "prefix-cache", "trace", "disagg"]);
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "serve" => cmd_serve(&args),
        "run" => cmd_run(&args),
        "bench" => cmd_bench(&args),
        "pack" => cmd_pack(&args),
        "info" => cmd_info(&args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
turbomind — mixed-precision LLM serving (TurboMind reproduction)

USAGE:
  turbomind serve [--addr HOST:PORT] [--precision WxAyKVz] [--backend sim|pjrt]
                  [--artifacts DIR] [--max-batch N] [--max-requests N]
                  [--device A100|H100|L40S|RTX4090] [--tp N]
                  [--prefix-cache] [--prefix-cache-blocks N]
                  [--preemption abort|swap|recompute|ladder] [--swap-budget-blocks N]
                  [--kv-layout l0:kv16,l1:kv8,...] [--kv-ladder off|auto]
                  [--replicas N] [--router-policy round_robin|least_loaded|prefix_affinity]
                  [--replica-spec fmt,kv,device[,tpN][,layout=…][,ladder=…]]...
                  [--queue-depth N] [--affinity-blocks N]
                  [--store-path FILE] [--store-pages N] [--page-size B]
                  [--trace] [--trace-ring N] [--trace-out FILE]
  turbomind run   [--requests N] [--replicas N] [--seed S] [--trace-out FILE]
                  [--disagg] [--prefill-replicas N] [--decode-replicas N]
                  [--prefill-spec fmt,kv,device[,…]]... [--decode-spec fmt,kv,device[,…]]...
                  [engine knobs as for serve]
  turbomind bench <fig11|fig12|...|fig28|table2|prefix_cache|preempt|router|ladder|disagg|hotpath|persist|all>
                  [--trace-out FILE]
  turbomind pack  [--k K] [--n N]
  turbomind info  [--artifacts DIR]

The default backend is `sim`: the deterministic pure-Rust execution backend
(no artifacts needed). `--backend pjrt` drives the AOT HLO artifacts and
requires a binary built with `--features pjrt`.

`--replicas N` (or any `--replica-spec`) serves a precision-heterogeneous
cluster instead of a single engine: N replicas, each with its own engine
thread, bounded queue, and (per `--replica-spec`, repeatable) its own
precision format, device profile, and TP degree — e.g.
`--replica-spec w4a16,kv8,a100 --replica-spec w8a8,kv16,h100`. An explicit
--replicas N wins: specs cycle to fill N (truncating when N is smaller);
with no specs, every replica inherits --precision/--device.
`--router-policy` picks how requests spread (prefix_affinity keeps
sessions with shared prompt prefixes on the replica caching them), and
`{\"stats\": true}` answers with the merged fleet line.

`--prefix-cache` enables the prefix-sharing KV cache: requests with a
common prompt prefix (shared system prompts, multi-turn histories) reuse
resident pool blocks instead of re-prefilling them; responses then report
`prefix_hit_tokens` and `{\"stats\": true}` reports the hit rate.

`--preemption swap|recompute` turns KV-pool exhaustion from an abort into
a scheduling decision: the precision-aware cost model picks a running
victim, swaps its quantized blocks to the host store (or releases them for
recompute), re-queues it at the head, and resumes it bit-exactly when
blocks free up. `--swap-budget-blocks` caps the host store (0 = unbounded);
`{\"stats\": true}` reports swap-pool utilization and victim counts.

`--kv-layout` admits the KV cache at a *per-layer* precision assignment
(e.g. `l0:kv16,l1:kv8,l2:kv8,l3:kv4`, or a uniform `kv8`); sim backend
only. `--kv-ladder auto` (with a lossless `--preemption` mode) lets the
engine transcode the whole pool down one precision rung in place under KV
pressure — freeing blocks without evicting anyone — before it falls back
to swap/recompute. Replica specs take the same knobs per replica as
`layout=l0:kv16;l1:kv8` (`;` between layers) and `ladder=auto` segments.
Responses report `ladder_count` + `final_kv_layout`, and `{\"stats\":
true}` reports the pool's current layout and ladder counters.

`--store-path FILE` opens (creating on first use) the page-file-backed KV
store (DESIGN.md §14). Swap preemption then persists victim snapshots to
disk instead of RAM, completed prompt blocks publish to a host-global
prefix store every replica shares (one prefill per *host*, not per
replica), and rerunning against the same file warm-starts: recovered
prefix blocks satisfy admissions bit-identically after a restart.
`--store-pages N` caps the file at N record pages (0 = unbounded; full ⇒
snapshots fall back to recompute, prefix publishes evict LRU), and
`--page-size B` sets the page geometry (power of two ≥ 256, default 4096;
must match the file being reopened). Disk traffic is priced on the
modeled clock and reported as `store_read`/`store_write` trace events.

`--trace` turns on the flight recorder (DESIGN.md §12): a bounded
wait-free ring of typed lifecycle events stamped with the modeled clock.
`{\"trace\": true}` answers the whole resident ring, `{\"trace\": N}` the
newest N events (single engine and cluster alike). `--trace-out FILE`
implies `--trace` and writes a Perfetto-loadable Chrome trace after a
bounded serve; `--trace-ring` sizes the ring (default 8192 events).

`run` is the offline flight-recorder driver: a deterministic, overloaded
`run_fleet` (defaults: 2 replicas, a small kv16 pool, swap preemption +
auto laddering, so preempt/ladder/swap events all fire). It reconciles
per-rung trace byte sums against the engine counters (exact equality),
validates the Chrome export, and writes it to `--trace-out`. Same seed ⇒
byte-identical trace file — the determinism contract CI enforces.

`run --disagg` serves the same workload disaggregated (DESIGN.md §13): a
prefill tier runs each prompt to its first token and exports the KV as a
layout-tagged snapshot; a decode tier imports it — transcoded host-side
to the destination's per-layer layout — and finishes the generation.
Tiers are sized with `--prefill-replicas`/`--decode-replicas` and typed
with repeatable `--prefill-spec`/`--decode-spec` (serve's replica-spec
syntax; specs cycle to fill the count). Defaults: one kv16 prefill
replica, one decode replica at --precision, so migration transcodes
kv16 → the decode layout. Migration traffic rides the PCIe model, shows
up as `migrate_out`/`migrate_in` trace events, and reconciles exactly
against per-replica telemetry. Because sampling is greedy, composed
outputs are bit-identical to a monolithic run at the decode layout.
";

fn engine_config(args: &Args) -> Result<EngineConfig> {
    let precision: PrecisionFormat = args
        .get_or("precision", "W4A16KV8")
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let backend: BackendKind = args
        .get_or("backend", "sim")
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(EngineConfig {
        backend,
        artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
        precision,
        device: args.get_or("device", "A100").to_string(),
        tp: args.get_usize("tp", 1),
        max_batch: args.get_usize("max-batch", 8),
        kv_pool_tokens: args.get_usize("kv-pool-tokens", 16 * 512),
        temperature: args.get_f64("temperature", 0.0) as f32,
        top_k: args.get_usize("top-k", 0),
        seed: args.get_u64("seed", 0),
        enable_prefix_cache: args.flag("prefix-cache"),
        prefix_cache_blocks: args.get_usize("prefix-cache-blocks", 0),
        preemption_mode: args
            .get_or("preemption", "abort")
            .parse()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        swap_budget_blocks: args.get_usize("swap-budget-blocks", 0),
        kv_layout: args.get("kv-layout").map(str::to_string),
        ladder_policy: args
            .get_or("kv-ladder", "off")
            .parse()
            .map_err(|e| anyhow::anyhow!("{e}"))?,
        // --trace-out implies recording: exporting an empty ring is never
        // what anyone wants.
        trace: args.flag("trace") || args.get("trace-out").is_some(),
        trace_ring_capacity: args
            .get_usize("trace-ring", turbomind::trace::DEFAULT_RING_CAPACITY),
        store: open_store(args)?,
        ..EngineConfig::default()
    })
}

/// `--store-path FILE` opens (or creates) the page-file-backed KV store
/// (DESIGN.md §14): the swap tier then persists snapshots to disk, prefix
/// blocks publish to the host-global store, and a restart against the
/// same file warm-starts from its recovered contents. `--store-pages N`
/// caps the file (0 = unbounded), `--page-size B` sets the page geometry
/// (power of two ≥ 256; must match an existing file).
fn open_store(args: &Args) -> Result<Option<std::sync::Arc<turbomind::store::PageFileStore>>> {
    let Some(path) = args.get("store-path") else {
        return Ok(None);
    };
    let page_size = args.get_usize("page-size", turbomind::store::DEFAULT_PAGE_SIZE);
    let max_pages = args.get_usize("store-pages", 0);
    let cfg = turbomind::store::StoreConfig::with_geometry(path, page_size, max_pages);
    let store = turbomind::store::PageFileStore::open(cfg)
        .map_err(|e| anyhow::anyhow!("opening --store-path {path}: {e}"))?;
    Ok(Some(store))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = engine_config(args)?;
    let addr = args.get_or("addr", "127.0.0.1:7181").to_string();
    let max_requests = args.get("max-requests").and_then(|v| v.parse().ok());

    // Cluster mode: any --replica-spec, or an explicit --replicas (a
    // `--replicas 1` fleet is still a cluster — router flags apply and
    // the stats probe answers the fleet schema).
    let spec_args = args.get_all("replica-spec");
    let replicas = args.get_usize("replicas", 0);
    if !spec_args.is_empty() || args.get("replicas").is_some() {
        let policy: RouterPolicy = args
            .get_or("router-policy", "least_loaded")
            .parse()
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut specs: Vec<ReplicaSpec> = spec_args
            .iter()
            .map(|s| s.parse().map_err(|e| anyhow::anyhow!("{e}")))
            .collect::<Result<_>>()?;
        if specs.is_empty() {
            specs.push(ReplicaSpec {
                precision: cfg.precision,
                device: cfg.device.clone(),
                tp: cfg.tp,
                kv_layout: None,
                ladder: None,
            });
        }
        // An explicit --replicas N wins: specs cycle to fill N (and
        // truncate when N is smaller); without it, one replica per spec.
        let n = if replicas > 0 { replicas } else { specs.len() };
        let specs: Vec<ReplicaSpec> =
            (0..n).map(|i| specs[i % specs.len()].clone()).collect();
        let mut ccfg = ClusterConfig::heterogeneous(cfg, specs, policy);
        ccfg.queue_depth = args.get_usize("queue-depth", 64);
        // Prompt blocks the prefix_affinity hash covers — size it to the
        // workload's stable shared prefix (DESIGN.md §9).
        ccfg.affinity_blocks = args.get_usize("affinity-blocks", 4);
        for (i, s) in ccfg.specs.iter().enumerate() {
            eprintln!("replica {i}: {}", s.label());
        }
        eprintln!("router policy: {policy} | {} replicas", ccfg.n_replicas());
        if args.get("trace-out").is_some() {
            eprintln!(
                "note: --trace-out file export is single-engine/`run` only; \
                 cluster rings answer the {{\"trace\": ...}} probe"
            );
        }
        let cluster = Cluster::start(ccfg)?;
        return server::serve_cluster(cluster, &addr, max_requests);
    }

    let engine = Engine::new(cfg)?;
    engine.warmup()?;
    eprintln!(
        "backend {} | model {} | precision {} | device {} | max_batch {}",
        engine.backend_name(),
        engine.model().name,
        engine.config().precision,
        engine.config().device,
        engine.config().max_batch
    );
    server::serve_with_trace_out(engine, &addr, max_requests, args.get("trace-out"))
}

/// The deterministic overloaded fleet run the flight recorder exists for:
/// small pool, swap preemption, auto laddering — every event class fires.
/// Reconciles trace byte sums against engine counters (exact equality),
/// validates the Chrome export, and writes it when `trace_out` is set.
fn traced_fleet_run(args: &Args, trace_out: Option<&str>) -> Result<()> {
    let mut base = engine_config(args)?;
    base.trace = true;
    // Pressure defaults — explicit flags always win.
    if args.get("kv-pool-tokens").is_none() {
        base.kv_pool_tokens = 16 * 64;
    }
    if args.get("preemption").is_none() {
        base.preemption_mode = PreemptionMode::Swap;
    }
    if args.get("kv-ladder").is_none() {
        base.ladder_policy = LadderPolicy::Auto;
    }
    if args.get("kv-layout").is_none() {
        // Admit wide so the ladder has rungs to descend.
        base.kv_layout = Some("kv16".into());
    }
    let n_replicas = args.get_usize("replicas", 2).max(1);
    let n_requests = args.get_usize("requests", 24);
    let policy: RouterPolicy = args
        .get_or("router-policy", "round_robin")
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed = args.get_u64("seed", 0);
    let ccfg = ClusterConfig::homogeneous(base, n_replicas, policy);

    // Deterministic synthetic overload: prompts outsize the pool in
    // aggregate, so admission control + preemption must both work.
    let mut rng = Rng::new(seed ^ 0x7ACE_F1EE7);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|_| {
            let plen = 24 + (rng.next_u64() % 48) as usize;
            let gen = 8 + (rng.next_u64() % 24) as usize;
            let prompt = (0..plen).map(|_| (rng.next_u64() % 512) as i32).collect();
            Request::new(prompt, gen)
        })
        .collect();

    let run = cluster::run_fleet(&ccfg, &reqs)?;
    eprintln!(
        "fleet: {} replicas | {} requests ({} completed) | makespan {:.4}s",
        n_replicas,
        n_requests,
        run.completed(),
        run.sim_makespan_s()
    );

    // The determinism/attribution contract: per-rung byte sums over the
    // trace events equal the engine counters exactly, replica by replica.
    let add = |acc: &mut [usize; 3], by: &[u64; 3]| {
        for (a, b) in acc.iter_mut().zip(by) {
            *a += *b as usize;
        }
    };
    for (snap, (label, dump)) in run.snapshots.iter().zip(&run.traces) {
        ensure!(dump.dropped == 0, "{label}: ring dropped {} events; raise --trace-ring", dump.dropped);
        let (mut gather, mut transcode, mut swapped, mut stored) =
            ([0usize; 3], [0usize; 3], [0usize; 3], [0usize; 3]);
        for ev in &dump.events {
            match &ev.kind {
                EventKind::PrefillChunk { gather_by_rung, .. }
                | EventKind::DecodeIter { gather_by_rung, .. } => add(&mut gather, gather_by_rung),
                EventKind::Ladder { bytes_by_rung, .. } => add(&mut transcode, bytes_by_rung),
                EventKind::SwapOut { bytes_by_rung, .. }
                | EventKind::SwapIn { bytes_by_rung, .. } => add(&mut swapped, bytes_by_rung),
                EventKind::StoreWrite { bytes_by_rung, .. }
                | EventKind::StoreRead { bytes_by_rung, .. } => add(&mut stored, bytes_by_rung),
                _ => {}
            }
        }
        ensure!(
            gather == snap.stats.gather_hbm_bytes_by_rung
                && gather.iter().sum::<usize>() == snap.stats.gather_hbm_bytes,
            "{label}: trace gather bytes {gather:?} != stats {:?}",
            snap.stats.gather_hbm_bytes_by_rung
        );
        ensure!(
            transcode == snap.telemetry.transcode_bytes_by_rung,
            "{label}: trace transcode bytes {transcode:?} != telemetry {:?}",
            snap.telemetry.transcode_bytes_by_rung
        );
        ensure!(
            swapped == snap.telemetry.swap_pcie_bytes_by_rung,
            "{label}: trace swap bytes {swapped:?} != telemetry {:?}",
            snap.telemetry.swap_pcie_bytes_by_rung
        );
        ensure!(
            stored == snap.telemetry.store_disk_bytes_by_rung,
            "{label}: trace store bytes {stored:?} != telemetry {:?}",
            snap.telemetry.store_disk_bytes_by_rung
        );
        eprintln!(
            "  {label}: {} events | gather {:?} B | transcode {:?} B | swap {:?} B | store {:?} B — reconciled",
            dump.events.len(),
            gather,
            transcode,
            swapped,
            stored
        );
    }
    let fleet = run.fleet_telemetry();
    eprintln!(
        "fleet telemetry (kv16/kv8/kv4): gather {:?} | transcode {:?} | swap {:?} | store {:?}",
        fleet.gather_hbm_bytes_by_rung,
        fleet.transcode_bytes_by_rung,
        fleet.swap_pcie_bytes_by_rung,
        fleet.store_disk_bytes_by_rung
    );
    if ccfg.base.store.is_some() {
        let hits: usize = run.snapshots.iter().map(|s| s.stats.store_prefix_hits).sum();
        let published: usize =
            run.snapshots.iter().map(|s| s.stats.store_published_blocks).sum();
        eprintln!("store: {hits} prefix adoptions | {published} blocks published");
    }

    let tracks = run.trace_tracks();
    let json = trace::chrome_trace(&tracks);
    trace::validate(&json)?;
    if let Some(path) = trace_out {
        trace::write_chrome(path, &tracks)?;
        let total: usize = run.traces.iter().map(|(_, d)| d.events.len()).sum();
        eprintln!("trace: {total} events across {} tracks -> {path}", tracks.len());
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    if args.flag("disagg") {
        traced_disagg_run(args, args.get("trace-out"))
    } else {
        traced_fleet_run(args, args.get("trace-out"))
    }
}

/// Build one tier's replica specs: repeatable `--{tier}-spec` flags,
/// cycled to fill an explicit `--{tier}-replicas N` (same semantics as
/// serve's `--replica-spec`/`--replicas`); with no specs, one replica of
/// the base precision/device, optionally with a tier-default KV layout.
fn tier_specs(
    args: &Args,
    base: &EngineConfig,
    spec_key: &str,
    count_key: &str,
    default_layout: Option<&str>,
) -> Result<Vec<ReplicaSpec>> {
    let mut specs: Vec<ReplicaSpec> = args
        .get_all(spec_key)
        .iter()
        .map(|s| s.parse().map_err(|e| anyhow::anyhow!("{e}")))
        .collect::<Result<_>>()?;
    if specs.is_empty() {
        specs.push(ReplicaSpec {
            precision: base.precision,
            device: base.device.clone(),
            tp: base.tp,
            kv_layout: default_layout.map(str::to_string),
            ladder: None,
        });
    }
    let n = args.get_usize(count_key, 0);
    let n = if n > 0 { n } else { specs.len() };
    Ok((0..n).map(|i| specs[i % specs.len()].clone()).collect())
}

/// `run --disagg`: the disaggregated analogue of [`traced_fleet_run`] —
/// same deterministic overload workload, but served by a prefill tier
/// and a decode tier with layout-tagged KV migration between them
/// (DESIGN.md §13). Reconciles per-rung *migration* byte sums over the
/// `migrate_out`/`migrate_in` trace events against each replica's
/// telemetry counter (exact equality), then validates/writes the Chrome
/// export like `run` does.
fn traced_disagg_run(args: &Args, trace_out: Option<&str>) -> Result<()> {
    let mut base = engine_config(args)?;
    base.trace = true;
    // Same pressure defaults as `run` — explicit flags always win. The
    // prefill tier admits wide (kv16) by default so migration into a
    // narrower decode pool actually transcodes.
    if args.get("kv-pool-tokens").is_none() {
        base.kv_pool_tokens = 16 * 64;
    }
    if args.get("preemption").is_none() {
        base.preemption_mode = PreemptionMode::Swap;
    }
    let prefill = tier_specs(args, &base, "prefill-spec", "prefill-replicas", Some("kv16"))?;
    let decode = tier_specs(args, &base, "decode-spec", "decode-replicas", None)?;
    let policy: RouterPolicy = args
        .get_or("router-policy", "round_robin")
        .parse()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let n_requests = args.get_usize("requests", 24);
    let seed = args.get_u64("seed", 0);
    let mut dcfg = DisaggConfig::new(base, prefill, decode, policy);
    dcfg.affinity_blocks = args.get_usize("affinity-blocks", 4);
    for (i, s) in dcfg.prefill_specs.iter().enumerate() {
        eprintln!("prefill replica {i}: {}", s.label());
    }
    for (i, s) in dcfg.decode_specs.iter().enumerate() {
        eprintln!("decode replica {i}: {}", s.label());
    }

    // The same deterministic synthetic overload `run` drives.
    let mut rng = Rng::new(seed ^ 0x7ACE_F1EE7);
    let reqs: Vec<Request> = (0..n_requests)
        .map(|_| {
            let plen = 24 + (rng.next_u64() % 48) as usize;
            let gen = 8 + (rng.next_u64() % 24) as usize;
            let prompt = (0..plen).map(|_| (rng.next_u64() % 512) as i32).collect();
            Request::new(prompt, gen)
        })
        .collect();

    let run = cluster::run_disagg(&dcfg, &reqs)?;
    eprintln!(
        "disagg: {}p + {}d replicas | {} requests ({} completed) | \
         {} migrated ({} recompute) | {} KV bytes shipped | makespan {:.4}s",
        dcfg.prefill_specs.len(),
        dcfg.decode_specs.len(),
        n_requests,
        run.completed(),
        run.migrated,
        run.recompute_migrations,
        run.migrated_bytes,
        run.sim_makespan_s()
    );

    // Migration attribution contract: per-rung byte sums over the
    // migrate events equal the telemetry counter exactly, replica by
    // replica (prefill replicas emit `migrate_out`, decode replicas
    // `migrate_in`; the counter is one per engine).
    let add = |acc: &mut [usize; 3], by: &[u64; 3]| {
        for (a, b) in acc.iter_mut().zip(by) {
            *a += *b as usize;
        }
    };
    let snaps = run.prefill_snapshots.iter().chain(&run.decode_snapshots);
    for (snap, (label, dump)) in snaps.zip(&run.traces) {
        ensure!(
            dump.dropped == 0,
            "{label}: ring dropped {} events; raise --trace-ring",
            dump.dropped
        );
        let mut migrate = [0usize; 3];
        for ev in &dump.events {
            match &ev.kind {
                EventKind::MigrateOut { bytes_by_rung, .. }
                | EventKind::MigrateIn { bytes_by_rung, .. } => add(&mut migrate, bytes_by_rung),
                _ => {}
            }
        }
        ensure!(
            migrate == snap.telemetry.migrate_pcie_bytes_by_rung,
            "{label}: trace migrate bytes {migrate:?} != telemetry {:?}",
            snap.telemetry.migrate_pcie_bytes_by_rung
        );
        eprintln!(
            "  {label}: {} events | migrate {:?} B — reconciled",
            dump.events.len(),
            migrate
        );
    }
    let fleet = run.fleet_telemetry();
    eprintln!(
        "fleet telemetry (kv16/kv8/kv4): migrate {:?} | swap {:?}",
        fleet.migrate_pcie_bytes_by_rung, fleet.swap_pcie_bytes_by_rung
    );

    let tracks = run.trace_tracks();
    let json = trace::chrome_trace(&tracks);
    trace::validate(&json)?;
    if let Some(path) = trace_out {
        trace::write_chrome(path, &tracks)?;
        let total: usize = run.traces.iter().map(|(_, d)| d.events.len()).sum();
        eprintln!("trace: {total} events across {} tracks -> {path}", tracks.len());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional().get(1).map(String::as_str).unwrap_or("all");
    if which == "all" {
        for (name, f) in bench::registry() {
            eprintln!("running {name}…");
            f().print();
        }
        return bench_trace_out(args);
    }
    match bench::run(which) {
        Some(t) => {
            t.print();
            bench_trace_out(args)
        }
        None => bail!(
            "unknown exhibit `{which}`; available: {:?}",
            bench::registry().iter().map(|(n, _)| *n).collect::<Vec<_>>()
        ),
    }
}

/// `bench --trace-out FILE`: after the exhibit, produce the standard
/// traced overload run (same driver as `run`) so a bench invocation can
/// also leave a Perfetto-loadable artifact behind.
fn bench_trace_out(args: &Args) -> Result<()> {
    match args.get("trace-out") {
        Some(path) => traced_fleet_run(args, Some(path)),
        None => Ok(()),
    }
}

fn cmd_pack(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 256);
    let n = args.get_usize("n", 4096);
    let mut rng = Rng::new(args.get_u64("seed", 0));
    let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
    let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64.min(k)));
    let packed = pack_weights_hw_aware(&q);

    println!("§4.1 hardware-aware weight packing — [{k} x {n}] INT4 (group 64)");
    println!("  tiles: {}   packed bytes: {}", packed.n_tiles(), packed.storage_bytes());

    // Verify the three guarantees on every tile.
    let mut worst_naive_tx = 0usize;
    let mut worst_naive_conflict = 0usize;
    for t in 0..packed.n_tiles().min(64) {
        let r = packed.runtime_load_report(t, 128);
        assert!(r.is_fully_coalesced() && r.is_conflict_free());
        let naive = analyze_global(&naive_fragment_access(n, t / (n / 16), t % (n / 16)), 128);
        worst_naive_tx = worst_naive_tx.max(naive.transactions);
        worst_naive_conflict = worst_naive_conflict.max(naive.bank_conflict_degree);
    }
    let packed_report = packed.runtime_load_report(0, 128);
    println!(
        "  packed layout : {} transactions / tile-pair, conflict degree {} (verified all tiles)",
        packed_report.transactions, packed_report.bank_conflict_degree
    );
    println!(
        "  naive layout  : up to {worst_naive_tx} transactions / tile, conflict degree {worst_naive_conflict}"
    );

    // Round-trip.
    let deq = packed.dequantize();
    let src = q.dequantize();
    assert_eq!(deq, src);
    println!("  round-trip    : exact (packed → unpack → dequantize == source)");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("device profiles:");
    for d in DeviceProfile::all() {
        println!(
            "  {:8} {:?}  mem {:.2} TB/s  f16 TC {:.0} TFLOPS  int8 TC {:.0} TOPS",
            d.name,
            d.arch,
            d.mem_bw / 1e12,
            d.tc_f16_flops / 1e12,
            d.tc_int8_ops / 1e12
        );
    }
    println!("\nmodel zoo:");
    for m in turbomind::config::model_zoo() {
        println!(
            "  {:24} L={} d={} heads={}/{} ffn={} params={:.1}B{}",
            m.name,
            m.n_layers,
            m.d_model,
            m.n_heads,
            m.n_kv_heads,
            m.d_ff,
            m.param_count() as f64 / 1e9,
            if m.is_moe() { " (MoE)" } else { "" }
        );
    }
    let dir = args.get_or("artifacts", "artifacts");
    match turbomind::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts in {dir}: {} graphs", m.graphs.len());
            for g in m.graphs.keys() {
                println!("  {g}");
            }
        }
        Err(e) => println!("\nartifacts: {e}"),
    }
    Ok(())
}

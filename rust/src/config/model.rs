//! Model architecture configs and the paper's evaluation model zoo (§5.1).
//!
//! The zoo entries carry the *real* architecture dimensions of the models the
//! paper benchmarks (Qwen / Llama / DeepSeek / Mixtral / QwQ families); they
//! drive the `gpusim` cost models at true scale. The `tiny()` config is the
//! ~13M-parameter Qwen-shaped model that actually executes end-to-end through
//! the PJRT runtime (DESIGN.md §1 substitutions).

/// Transformer architecture description (decoder-only, GQA, SwiGLU).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Human-readable name, e.g. `qwen3-8b`.
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// Grouped-query attention KV heads (== n_heads for MHA).
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// SwiGLU intermediate size.
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_seq_len: usize,
    /// MoE expert count (1 = dense). Mixtral-style top-2 routing assumed.
    pub n_experts: usize,
    /// Active experts per token for MoE (ignored when `n_experts == 1`).
    pub experts_per_token: usize,
}

impl ModelConfig {
    /// The tiny Qwen-shaped model compiled to HLO artifacts and executed by
    /// the real engine. Dimensions chosen so every GEMM is MXU-tile friendly
    /// (multiples of 128 where it matters) while keeping artifacts small.
    pub fn tiny() -> Self {
        Self {
            name: "tiny-qwen".into(),
            n_layers: 4,
            d_model: 256,
            n_heads: 8,
            n_kv_heads: 4,
            head_dim: 32,
            d_ff: 768,
            vocab_size: 2048,
            max_seq_len: 512,
            n_experts: 1,
            experts_per_token: 1,
        }
    }

    fn dense(
        name: &str,
        n_layers: usize,
        d_model: usize,
        n_heads: usize,
        n_kv_heads: usize,
        d_ff: usize,
        vocab_size: usize,
    ) -> Self {
        Self {
            name: name.into(),
            n_layers,
            d_model,
            n_heads,
            n_kv_heads,
            head_dim: d_model / n_heads,
            d_ff,
            vocab_size,
            max_seq_len: 32_768,
            n_experts: 1,
            experts_per_token: 1,
        }
    }

    /// Q/K/V/O projection shapes per layer as `(name, rows_in, cols_out)`.
    /// These are the GEMMs the paper's GEMM pipeline accelerates.
    pub fn layer_gemms(&self) -> Vec<(&'static str, usize, usize)> {
        let kv_out = self.n_kv_heads * self.head_dim;
        let q_out = self.n_heads * self.head_dim;
        let mut v = vec![
            ("wq", self.d_model, q_out),
            ("wk", self.d_model, kv_out),
            ("wv", self.d_model, kv_out),
            ("wo", q_out, self.d_model),
        ];
        // SwiGLU: gate + up + down. For MoE these exist per active expert.
        let ff_mult = self.experts_per_token.max(1);
        for _ in 0..ff_mult {
            v.push(("w_gate", self.d_model, self.d_ff));
            v.push(("w_up", self.d_model, self.d_ff));
            v.push(("w_down", self.d_ff, self.d_model));
        }
        v
    }

    /// Total parameter count (embeddings + layers + head), for sizing checks.
    pub fn param_count(&self) -> usize {
        let embed = self.vocab_size * self.d_model * 2; // tok embed + lm head
        let per_layer: usize = self
            .layer_gemms_all_experts()
            .iter()
            .map(|(_, r, c)| r * c)
            .sum::<usize>()
            + 2 * self.d_model; // rmsnorm scales
        embed + self.n_layers * per_layer + self.d_model
    }

    /// Like `layer_gemms` but counting *all* experts (for memory footprint).
    fn layer_gemms_all_experts(&self) -> Vec<(&'static str, usize, usize)> {
        let kv_out = self.n_kv_heads * self.head_dim;
        let q_out = self.n_heads * self.head_dim;
        let mut v = vec![
            ("wq", self.d_model, q_out),
            ("wk", self.d_model, kv_out),
            ("wv", self.d_model, kv_out),
            ("wo", q_out, self.d_model),
        ];
        for _ in 0..self.n_experts.max(1) {
            v.push(("w_gate", self.d_model, self.d_ff));
            v.push(("w_up", self.d_model, self.d_ff));
            v.push(("w_down", self.d_ff, self.d_model));
        }
        v
    }

    /// Weight bytes at `w_bits` weight precision (scales excluded).
    pub fn weight_bytes(&self, w_bits: usize) -> usize {
        self.param_count() * w_bits / 8
    }

    /// KV cache bytes per token at `kv_bits` (both K and V, all layers).
    pub fn kv_bytes_per_token(&self, kv_bits: usize) -> usize {
        2 * self.n_layers * self.n_kv_heads * self.head_dim * kv_bits / 8
    }

    pub fn is_moe(&self) -> bool {
        self.n_experts > 1
    }

    /// Static per-layer KV importance in `(0, 1]`, used by the precision
    /// ladder to pick which layer to downgrade next (least important first).
    /// Early layers feed every later one, so importance decays linearly with
    /// depth: `imp[l] = (n - l) / n`. Deliberately a static prior — the
    /// ladder only needs an *ordering*, and a deterministic one keeps
    /// restarted generations bit-identical.
    pub fn layer_importance(&self) -> Vec<f64> {
        layer_importance(self.n_layers)
    }
}

/// See [`ModelConfig::layer_importance`].
pub fn layer_importance(n_layers: usize) -> Vec<f64> {
    let n = n_layers.max(1) as f64;
    (0..n_layers).map(|l| (n - l as f64) / n).collect()
}

/// The 16-model evaluation zoo of §5.1 / Fig 15, with true architecture
/// dimensions from the public model cards.
pub fn model_zoo() -> Vec<ModelConfig> {
    let mut zoo = vec![
        // Qwen3 family (dense)
        ModelConfig::dense("qwen3-8b", 36, 4096, 32, 8, 12288, 151_936),
        ModelConfig::dense("qwen3-14b", 40, 5120, 40, 8, 17408, 151_936),
        ModelConfig::dense("qwen3-32b", 64, 5120, 64, 8, 25600, 151_936),
        // Qwen2.5 family
        ModelConfig::dense("qwen2.5-7b", 28, 3584, 28, 4, 18944, 152_064),
        ModelConfig::dense("qwen2.5-14b", 48, 5120, 40, 8, 13824, 152_064),
        ModelConfig::dense("qwen2.5-32b", 64, 5120, 40, 8, 27648, 152_064),
        ModelConfig::dense("qwen2.5-72b", 80, 8192, 64, 8, 29568, 152_064),
        // Llama-3 family
        ModelConfig::dense("llama3-8b", 32, 4096, 32, 8, 14336, 128_256),
        ModelConfig::dense("llama3-70b", 80, 8192, 64, 8, 28672, 128_256),
        // DeepSeek distills (Qwen/Llama backbones)
        ModelConfig::dense("deepseek-r1-distill-7b", 28, 3584, 28, 4, 18944, 152_064),
        ModelConfig::dense("deepseek-r1-distill-70b", 80, 8192, 64, 8, 28672, 128_256),
        // Reasoning model (Fig 16)
        ModelConfig::dense("qwq-32b", 64, 5120, 40, 8, 27648, 152_064),
    ];

    // MoE models (Mixtral family + Qwen3 235B), §5.1.
    let mut mixtral_8x7b = ModelConfig::dense("mixtral-8x7b", 32, 4096, 32, 8, 14336, 32_000);
    mixtral_8x7b.n_experts = 8;
    mixtral_8x7b.experts_per_token = 2;
    let mut mixtral_8x22b = ModelConfig::dense("mixtral-8x22b", 56, 6144, 48, 8, 16384, 32_768);
    mixtral_8x22b.n_experts = 8;
    mixtral_8x22b.experts_per_token = 2;
    let mut qwen3_235b = ModelConfig::dense("qwen3-235b-a22b", 94, 4096, 64, 4, 1536, 151_936);
    qwen3_235b.n_experts = 128;
    qwen3_235b.experts_per_token = 8;

    zoo.push(mixtral_8x7b);
    zoo.push(mixtral_8x22b);
    zoo.push(qwen3_235b);
    zoo.push(ModelConfig::tiny());
    zoo
}

/// Look up a zoo model by name.
pub fn find_model(name: &str) -> Option<ModelConfig> {
    model_zoo().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_16_models() {
        assert_eq!(model_zoo().len(), 16);
    }

    #[test]
    fn zoo_names_unique() {
        let zoo = model_zoo();
        let mut names: Vec<_> = zoo.iter().map(|m| m.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), zoo.len());
    }

    #[test]
    fn param_counts_roughly_match_names() {
        // Sanity: the "8B" model should be within 25% of 8e9 params.
        let m = find_model("qwen3-8b").unwrap();
        let p = m.param_count() as f64;
        assert!((6e9..1.05e10).contains(&p), "qwen3-8b params {p:e}");
        let m70 = find_model("llama3-70b").unwrap();
        let p70 = m70.param_count() as f64;
        assert!((6e10..8.5e10).contains(&p70), "llama3-70b params {p70:e}");
    }

    #[test]
    fn tiny_model_is_small_and_aligned() {
        let t = ModelConfig::tiny();
        assert!(t.param_count() < 20_000_000, "params {}", t.param_count());
        assert_eq!(t.n_heads * t.head_dim, t.d_model);
        assert_eq!(t.d_model % 128, 0);
    }

    #[test]
    fn gqa_ratio_divides() {
        for m in model_zoo() {
            assert_eq!(m.n_heads % m.n_kv_heads, 0, "{}", m.name);
        }
    }

    #[test]
    fn kv_bytes_scale_with_precision() {
        let m = find_model("qwen3-8b").unwrap();
        let kv16 = m.kv_bytes_per_token(16);
        let kv8 = m.kv_bytes_per_token(8);
        let kv4 = m.kv_bytes_per_token(4);
        assert_eq!(kv16, 2 * kv8);
        assert_eq!(kv8, 2 * kv4);
    }

    #[test]
    fn moe_flagged() {
        assert!(find_model("mixtral-8x22b").unwrap().is_moe());
        assert!(!find_model("qwen3-8b").unwrap().is_moe());
    }

    #[test]
    fn layer_importance_is_monotone_decreasing() {
        let imp = ModelConfig::tiny().layer_importance();
        assert_eq!(imp.len(), 4);
        assert!(imp.windows(2).all(|w| w[0] > w[1]), "{imp:?}");
        assert!((imp[0] - 1.0).abs() < 1e-12, "first layer most important");
        assert!(imp[3] > 0.0, "importance stays positive");
        assert!(layer_importance(0).is_empty());
    }

    #[test]
    fn weight_bytes_compression() {
        let m = find_model("qwen3-8b").unwrap();
        assert_eq!(m.weight_bytes(16), 4 * m.weight_bytes(4));
    }
}

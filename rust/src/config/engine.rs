//! Serving-engine configuration: the knobs a deployment would set.

use super::precision::{DType, PrecisionFormat};

/// Which execution backend the engine drives.
///
/// `Sim` is the default: the deterministic pure-Rust backend that runs
/// everywhere with no artifacts. `Pjrt` executes the AOT-compiled HLO
/// graphs and requires building with `--features pjrt` plus an artifacts
/// directory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Sim,
    Pjrt,
}

impl std::str::FromStr for BackendKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(BackendKind::Sim),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(format!("unknown backend `{other}` (expected `sim` or `pjrt`)")),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Sim => "sim",
            BackendKind::Pjrt => "pjrt",
        })
    }
}

/// What the engine does when the KV pool runs dry mid-flight (DESIGN.md
/// §8): abort the victim (legacy), swap its blocks to the host-side store,
/// or drop them and recompute the prefix on resume. Swap and recompute are
/// **lossless**: the victim re-queues at the head and its final output is
/// bit-identical to an unpressured run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptionMode {
    /// Finish the victim with `FinishReason::Aborted` (partial generation
    /// is still returned). The pre-preemption behavior, and the default.
    #[default]
    Abort,
    /// Copy the victim's KV blocks to the host swap store and restore them
    /// when blocks free up; falls back to recompute for victims whose
    /// tokens the prefix index already holds (or when the swap budget is
    /// full) — whichever the cost model prices cheaper.
    Swap,
    /// Release the victim's blocks and re-prefill its prompt + generated
    /// prefix on resume (cheap for short or prefix-cached sequences).
    Recompute,
    /// Before swapping or recomputing, try to *ladder* the whole pool down
    /// one precision rung (in-place transcode of every resident block, e.g.
    /// kv16 → one layer at kv8), freeing capacity without any eviction.
    /// Falls back to swap-or-recompute pricing once the ladder is exhausted
    /// (all layers already kv4) or the rung would not free enough.
    Ladder,
}

impl std::str::FromStr for PreemptionMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "abort" => Ok(PreemptionMode::Abort),
            "swap" => Ok(PreemptionMode::Swap),
            "recompute" => Ok(PreemptionMode::Recompute),
            "ladder" => Ok(PreemptionMode::Ladder),
            other => Err(format!(
                "unknown preemption mode `{other}` (expected `abort`, `swap`, `recompute`, or `ladder`)"
            )),
        }
    }
}

impl std::fmt::Display for PreemptionMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            PreemptionMode::Abort => "abort",
            PreemptionMode::Swap => "swap",
            PreemptionMode::Recompute => "recompute",
            PreemptionMode::Ladder => "ladder",
        })
    }
}

/// Whether the engine may ladder the pool's per-layer KV precision down
/// under memory pressure (`--kv-ladder`). Separate from [`PreemptionMode`]
/// so `ladder` preemption can be requested while the policy stays `Off`
/// (it then degenerates to swap pricing — useful as an ablation control).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LadderPolicy {
    /// Never transcode; the admission layout is final.
    #[default]
    Off,
    /// Ladder the least-important still-wide layer down one rung whenever
    /// the preemption cost model prices it below eviction.
    Auto,
}

impl std::str::FromStr for LadderPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(LadderPolicy::Off),
            "auto" => Ok(LadderPolicy::Auto),
            other => Err(format!("unknown ladder policy `{other}` (expected `off` or `auto`)")),
        }
    }
}

impl std::fmt::Display for LadderPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            LadderPolicy::Off => "off",
            LadderPolicy::Auto => "auto",
        })
    }
}

/// Configuration of the serving engine.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Execution backend (see [`BackendKind`]).
    pub backend: BackendKind,
    /// Directory holding `manifest.json` + `*.hlo.txt` + weight binaries
    /// (PJRT backend only).
    pub artifacts_dir: String,
    /// Mixed-precision format to serve with. Must match a compiled variant.
    pub precision: PrecisionFormat,
    /// Device profile name the sim backend's latency model runs on
    /// (`A100` default; any [`super::DeviceProfile::by_name`] entry). In a
    /// precision-heterogeneous cluster each replica sets its own — the
    /// "hardware-aware format optimization" axis of the paper's §4.1.
    pub device: String,
    /// Tensor-parallel degree of this engine's modeled device group (1 =
    /// single GPU). Feeds the sim backend's iteration-latency model only;
    /// the executed tiny model is never actually sharded.
    pub tp: usize,
    /// Maximum concurrent decode batch (must be a compiled decode batch
    /// size; smaller batches run padded to the next compiled size).
    pub max_batch: usize,
    /// KV block size in tokens (paged KV cache granularity).
    pub kv_block_tokens: usize,
    /// Total KV pool budget in tokens (across all sequences).
    pub kv_pool_tokens: usize,
    /// Maximum new tokens per request unless the request caps it lower.
    pub max_new_tokens: usize,
    /// Chunk size for prefill (longer prompts run in chunks, Sarathi-style).
    pub prefill_chunk: usize,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f32,
    /// Top-k sampling cutoff (0 = disabled).
    pub top_k: usize,
    /// RNG seed for sampling.
    pub seed: u64,
    /// Scheduler policy.
    pub scheduler: SchedulerPolicy,
    /// Enable the precision-keyed prefix-sharing KV cache: matched full
    /// prompt blocks are reused from the pool (ref-counted, copy-on-write)
    /// instead of being re-prefilled. Off by default — with it on, finished
    /// requests intentionally leave their prompt blocks resident.
    pub enable_prefix_cache: bool,
    /// Prefix-cache budget in KV blocks (0 = bounded only by the pool).
    /// Ignored unless `enable_prefix_cache` is set.
    pub prefix_cache_blocks: usize,
    /// Reaction to KV-pool exhaustion mid-flight (see [`PreemptionMode`]).
    pub preemption_mode: PreemptionMode,
    /// Host swap-store budget in KV blocks (0 = unbounded). Only consulted
    /// in `PreemptionMode::Swap`; a victim that would overflow the budget
    /// is recomputed instead.
    pub swap_budget_blocks: usize,
    /// Per-layer KV admission layout, e.g. `l0:kv16,l1:kv8,...` or a
    /// uniform `kv8`. `None` derives a uniform layout from
    /// `precision.kv` (the pre-layout behavior). Parsed against the model's
    /// layer count by the engine at construction.
    pub kv_layout: Option<String>,
    /// In-place precision-laddering policy (see [`LadderPolicy`]).
    pub ladder_policy: LadderPolicy,
    /// Record lifecycle events into the flight-recorder ring (DESIGN.md
    /// §12). Off by default: the disabled path is a single branch per
    /// would-be event, so serving hot-path ratios are unaffected.
    pub trace: bool,
    /// Flight-recorder ring capacity in events (oldest events are
    /// overwritten once exceeded; the drop count is exact).
    pub trace_ring_capacity: usize,
    /// Shared page-file store (DESIGN.md §14). When set, the swap tier is
    /// page-file-backed (snapshots persist, disk-tier pricing applies) and
    /// the engine adopts/publishes prefix blocks host-globally. Replicas
    /// sharing one `Arc` share one store.
    pub store: Option<std::sync::Arc<crate::store::PageFileStore>>,
}

/// Iteration-level scheduling policy (§5 serving comparisons; the
/// `Static` policy exists as the ablation baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// vLLM/Orca-style continuous batching: decode-priority with prefill
    /// admission whenever KV + batch budget allow.
    Continuous,
    /// Static batching: wait for a full batch, run it to completion.
    Static,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            backend: BackendKind::Sim,
            artifacts_dir: "artifacts".into(),
            precision: PrecisionFormat::new(DType::Int4, DType::F16, DType::Int8),
            device: "A100".into(),
            tp: 1,
            max_batch: 8,
            kv_block_tokens: 16,
            kv_pool_tokens: 16 * 512,
            max_new_tokens: 64,
            prefill_chunk: 128,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            scheduler: SchedulerPolicy::Continuous,
            enable_prefix_cache: false,
            prefix_cache_blocks: 0,
            preemption_mode: PreemptionMode::Abort,
            swap_budget_blocks: 0,
            kv_layout: None,
            ladder_policy: LadderPolicy::Off,
            trace: false,
            trace_ring_capacity: crate::trace::DEFAULT_RING_CAPACITY,
            store: None,
        }
    }
}

impl EngineConfig {
    /// Validate internal consistency; returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be > 0".into());
        }
        if !self.max_batch.is_power_of_two() {
            return Err(format!(
                "max_batch {} must be a power of two (compiled decode batch sizes)",
                self.max_batch
            ));
        }
        if self.kv_block_tokens == 0 || self.kv_pool_tokens == 0 {
            return Err("kv pool sizes must be > 0".into());
        }
        if self.kv_pool_tokens % self.kv_block_tokens != 0 {
            return Err(format!(
                "kv_pool_tokens {} must be a multiple of kv_block_tokens {}",
                self.kv_pool_tokens, self.kv_block_tokens
            ));
        }
        if self.prefill_chunk == 0 {
            return Err("prefill_chunk must be > 0".into());
        }
        if super::DeviceProfile::by_name(&self.device).is_none() {
            return Err(format!("unknown device profile `{}`", self.device));
        }
        if self.tp == 0 || !self.tp.is_power_of_two() {
            return Err(format!("tp degree {} must be a power of two", self.tp));
        }
        if self.temperature < 0.0 {
            return Err("temperature must be >= 0".into());
        }
        if self.enable_prefix_cache
            && self.prefix_cache_blocks > self.kv_pool_tokens / self.kv_block_tokens
        {
            return Err(format!(
                "prefix_cache_blocks {} exceeds the pool's {} blocks",
                self.prefix_cache_blocks,
                self.kv_pool_tokens / self.kv_block_tokens
            ));
        }
        if let Some(spec) = &self.kv_layout {
            if spec.trim().is_empty() {
                return Err("kv_layout must not be empty (omit the flag for the default)".into());
            }
        }
        if self.ladder_policy == LadderPolicy::Auto && self.preemption_mode == PreemptionMode::Abort
        {
            return Err(
                "ladder_policy auto requires a lossless preemption mode (swap, recompute, or \
                 ladder) — abort would discard the victims laddering is meant to save"
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        let c = EngineConfig::default();
        c.validate().unwrap();
        assert_eq!(c.backend, BackendKind::Sim, "hermetic default");
    }

    #[test]
    fn preemption_mode_parses() {
        assert_eq!("abort".parse::<PreemptionMode>().unwrap(), PreemptionMode::Abort);
        assert_eq!("Swap".parse::<PreemptionMode>().unwrap(), PreemptionMode::Swap);
        assert_eq!("RECOMPUTE".parse::<PreemptionMode>().unwrap(), PreemptionMode::Recompute);
        assert_eq!("ladder".parse::<PreemptionMode>().unwrap(), PreemptionMode::Ladder);
        assert!("drop".parse::<PreemptionMode>().is_err());
        assert_eq!(PreemptionMode::Swap.to_string(), "swap");
        assert_eq!(PreemptionMode::Ladder.to_string(), "ladder");
        assert_eq!(PreemptionMode::default(), PreemptionMode::Abort, "legacy default");
    }

    #[test]
    fn ladder_policy_parses_and_validates() {
        assert_eq!("off".parse::<LadderPolicy>().unwrap(), LadderPolicy::Off);
        assert_eq!("AUTO".parse::<LadderPolicy>().unwrap(), LadderPolicy::Auto);
        assert!("always".parse::<LadderPolicy>().is_err());
        assert_eq!(LadderPolicy::Auto.to_string(), "auto");
        assert_eq!(LadderPolicy::default(), LadderPolicy::Off);

        let mut c = EngineConfig::default();
        c.ladder_policy = LadderPolicy::Auto;
        assert!(c.validate().is_err(), "auto laddering atop abort preemption is rejected");
        c.preemption_mode = PreemptionMode::Ladder;
        c.validate().unwrap();

        let mut c = EngineConfig::default();
        c.kv_layout = Some("  ".into());
        assert!(c.validate().is_err(), "blank layout spec rejected");
        c.kv_layout = Some("l0:kv16,l1:kv8".into());
        c.validate().unwrap();
    }

    #[test]
    fn backend_kind_parses() {
        assert_eq!("sim".parse::<BackendKind>().unwrap(), BackendKind::Sim);
        assert_eq!("PJRT".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert!("tpu".parse::<BackendKind>().is_err());
        assert_eq!(BackendKind::Sim.to_string(), "sim");
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = EngineConfig::default();
        c.max_batch = 0;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.max_batch = 3;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.kv_pool_tokens = 100;
        c.kv_block_tokens = 16;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.temperature = -1.0;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.device = "B200".into();
        assert!(c.validate().is_err(), "unknown device profile");
        c.device = "h100".into();
        c.validate().unwrap();

        let mut c = EngineConfig::default();
        c.tp = 3;
        assert!(c.validate().is_err(), "non-pow2 tp");
        c.tp = 4;
        c.validate().unwrap();

        let mut c = EngineConfig::default();
        c.enable_prefix_cache = true;
        c.prefix_cache_blocks = c.kv_pool_tokens / c.kv_block_tokens + 1;
        assert!(c.validate().is_err(), "cache budget larger than the pool");
        c.prefix_cache_blocks = 8;
        c.validate().unwrap();
    }
}

//! Serving-engine configuration: the knobs a deployment would set.

use super::precision::{DType, PrecisionFormat};

/// Configuration of the real (PJRT-backed) serving engine.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt` + weight binaries.
    pub artifacts_dir: String,
    /// Mixed-precision format to serve with. Must match a compiled variant.
    pub precision: PrecisionFormat,
    /// Maximum concurrent decode batch (must be a compiled decode batch
    /// size; smaller batches run padded to the next compiled size).
    pub max_batch: usize,
    /// KV block size in tokens (paged KV cache granularity).
    pub kv_block_tokens: usize,
    /// Total KV pool budget in tokens (across all sequences).
    pub kv_pool_tokens: usize,
    /// Maximum new tokens per request unless the request caps it lower.
    pub max_new_tokens: usize,
    /// Chunk size for prefill (longer prompts run in chunks, Sarathi-style).
    pub prefill_chunk: usize,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f32,
    /// Top-k sampling cutoff (0 = disabled).
    pub top_k: usize,
    /// RNG seed for sampling.
    pub seed: u64,
    /// Scheduler policy.
    pub scheduler: SchedulerPolicy,
}

/// Iteration-level scheduling policy (§5 serving comparisons; the
/// `Static` policy exists as the ablation baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// vLLM/Orca-style continuous batching: decode-priority with prefill
    /// admission whenever KV + batch budget allow.
    Continuous,
    /// Static batching: wait for a full batch, run it to completion.
    Static,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            precision: PrecisionFormat::new(DType::Int4, DType::F16, DType::Int8),
            max_batch: 8,
            kv_block_tokens: 16,
            kv_pool_tokens: 16 * 512,
            max_new_tokens: 64,
            prefill_chunk: 128,
            temperature: 0.0,
            top_k: 0,
            seed: 0,
            scheduler: SchedulerPolicy::Continuous,
        }
    }
}

impl EngineConfig {
    /// Validate internal consistency; returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be > 0".into());
        }
        if !self.max_batch.is_power_of_two() {
            return Err(format!(
                "max_batch {} must be a power of two (compiled decode batch sizes)",
                self.max_batch
            ));
        }
        if self.kv_block_tokens == 0 || self.kv_pool_tokens == 0 {
            return Err("kv pool sizes must be > 0".into());
        }
        if self.kv_pool_tokens % self.kv_block_tokens != 0 {
            return Err(format!(
                "kv_pool_tokens {} must be a multiple of kv_block_tokens {}",
                self.kv_pool_tokens, self.kv_block_tokens
            ));
        }
        if self.prefill_chunk == 0 {
            return Err("prefill_chunk must be > 0".into());
        }
        if self.temperature < 0.0 {
            return Err("temperature must be >= 0".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = EngineConfig::default();
        c.max_batch = 0;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.max_batch = 3;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.kv_pool_tokens = 100;
        c.kv_block_tokens = 16;
        assert!(c.validate().is_err());

        let mut c = EngineConfig::default();
        c.temperature = -1.0;
        assert!(c.validate().is_err());
    }
}

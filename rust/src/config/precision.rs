//! Precision formats: element dtypes and the paper's `WxAyKVz` notation.

use std::fmt;
use std::str::FromStr;

/// Element data types used across weights, activations, and KV cache.
///
/// `F32` stands in for the paper's FP16 "full precision" on the CPU-PJRT
/// testbed (see DESIGN.md §1); the *relative* behaviour of the quantized
/// formats against it is what the experiments measure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    /// 4-bit signed integer (packed two-per-byte).
    Int4,
    /// 8-bit signed integer.
    Int8,
    /// 8-bit float (e5m2); modeled in gpusim, stored as one byte.
    Fp8,
    /// 16-bit float (the paper's FP16/BF16 tier).
    F16,
    /// 32-bit float (CPU-PJRT stand-in for full precision).
    F32,
}

impl DType {
    /// Number of bits per element.
    pub const fn bits(self) -> usize {
        match self {
            DType::Int4 => 4,
            DType::Int8 | DType::Fp8 => 8,
            DType::F16 => 16,
            DType::F32 => 32,
        }
    }

    /// Bytes needed to store `n` elements of this dtype (Int4 packs two per
    /// byte; `n` odd rounds up).
    pub const fn bytes_for(self, n: usize) -> usize {
        (n * self.bits()).div_ceil(8)
    }

    /// True for integer quantized formats that need scales + I2F dequant.
    pub const fn is_quantized(self) -> bool {
        matches!(self, DType::Int4 | DType::Int8 | DType::Fp8)
    }

    /// The maximum representable magnitude for symmetric integer quant.
    pub const fn qmax(self) -> i32 {
        match self {
            DType::Int4 => 7,
            DType::Int8 => 127,
            _ => 0,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DType::Int4 => "int4",
            DType::Int8 => "int8",
            DType::Fp8 => "fp8",
            DType::F16 => "f16",
            DType::F32 => "f32",
        };
        f.write_str(s)
    }
}

/// A `WxAyKVz` mixed-precision format: x-bit weights, y-bit activations,
/// z-bit KV cache (paper §1, footnote 1). Examples: `W4A16KV8`, `W16A16KV16`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrecisionFormat {
    pub weight: DType,
    pub activation: DType,
    pub kv: DType,
}

impl PrecisionFormat {
    pub const fn new(weight: DType, activation: DType, kv: DType) -> Self {
        Self { weight, activation, kv }
    }

    /// The paper's headline TurboMind format (Fig 20): W4A16KV4.
    pub const fn w4a16kv4() -> Self {
        Self::new(DType::Int4, DType::F16, DType::Int4)
    }

    /// The micro-benchmark format of Figs 11-12: W4A16KV8.
    pub const fn w4a16kv8() -> Self {
        Self::new(DType::Int4, DType::F16, DType::Int8)
    }

    /// Full-precision baseline: W16A16KV16.
    pub const fn full() -> Self {
        Self::new(DType::F16, DType::F16, DType::F16)
    }

    /// QServe's hard-wired format (§2): W4A8KV4.
    pub const fn w4a8kv4() -> Self {
        Self::new(DType::Int4, DType::Int8, DType::Int4)
    }

    /// Weight compression ratio versus 16-bit weights (ignoring scales).
    pub fn weight_compression(&self) -> f64 {
        16.0 / self.weight.bits() as f64
    }

    /// KV compression ratio versus 16-bit KV.
    pub fn kv_compression(&self) -> f64 {
        16.0 / self.kv.bits() as f64
    }
}

impl fmt::Display for PrecisionFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "W{}A{}KV{}",
            self.weight.bits(),
            self.activation.bits(),
            self.kv.bits()
        )
    }
}

/// Errors from parsing a `WxAyKVz` string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsePrecisionError(String);

impl fmt::Display for ParsePrecisionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "invalid precision format `{}` (expected e.g. W4A16KV8)", self.0)
    }
}

impl std::error::Error for ParsePrecisionError {}

impl FromStr for PrecisionFormat {
    type Err = ParsePrecisionError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let err = || ParsePrecisionError(s.to_string());
        let upper = s.to_ascii_uppercase();
        let rest = upper.strip_prefix('W').ok_or_else(err)?;
        let a_pos = rest.find('A').ok_or_else(err)?;
        let (w_bits, rest) = rest.split_at(a_pos);
        let rest = rest.strip_prefix('A').ok_or_else(err)?;
        let kv_pos = rest.find("KV").ok_or_else(err)?;
        let (a_bits, rest) = rest.split_at(kv_pos);
        let kv_bits = rest.strip_prefix("KV").ok_or_else(err)?;

        let parse_bits = |bits: &str, fp8_ok: bool| -> Result<DType, ParsePrecisionError> {
            match bits {
                "4" => Ok(DType::Int4),
                "8" => Ok(DType::Int8),
                "8F" if fp8_ok => Ok(DType::Fp8),
                "16" => Ok(DType::F16),
                "32" => Ok(DType::F32),
                _ => Err(ParsePrecisionError(s.to_string())),
            }
        };
        Ok(PrecisionFormat {
            weight: parse_bits(w_bits, true)?,
            activation: parse_bits(a_bits, true)?,
            kv: parse_bits(kv_bits, true)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::Int4.bytes_for(8), 4);
        assert_eq!(DType::Int4.bytes_for(7), 4); // rounds up
        assert_eq!(DType::Int8.bytes_for(8), 8);
        assert_eq!(DType::F16.bytes_for(8), 16);
        assert_eq!(DType::F32.bytes_for(8), 32);
    }

    #[test]
    fn qmax_values() {
        assert_eq!(DType::Int4.qmax(), 7);
        assert_eq!(DType::Int8.qmax(), 127);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["W4A16KV8", "W16A16KV16", "W4A8KV4", "W8A16KV16", "W4A16KV4"] {
            let p: PrecisionFormat = s.parse().unwrap();
            assert_eq!(p.to_string(), s, "roundtrip {s}");
        }
    }

    #[test]
    fn parse_case_insensitive() {
        let p: PrecisionFormat = "w4a16kv8".parse().unwrap();
        assert_eq!(p, PrecisionFormat::w4a16kv8());
    }

    #[test]
    fn parse_fp8() {
        let p: PrecisionFormat = "W8FA16KV8F".parse().unwrap();
        assert_eq!(p.weight, DType::Fp8);
        assert_eq!(p.kv, DType::Fp8);
    }

    #[test]
    fn parse_rejects_invalid() {
        for s in ["", "W4", "W4A16", "4A16KV8", "W3A16KV8", "W4A16KV2"] {
            assert!(s.parse::<PrecisionFormat>().is_err(), "should reject {s}");
        }
    }

    #[test]
    fn compression_ratios() {
        assert_eq!(PrecisionFormat::w4a16kv8().weight_compression(), 4.0);
        assert_eq!(PrecisionFormat::w4a16kv8().kv_compression(), 2.0);
        assert_eq!(PrecisionFormat::full().weight_compression(), 1.0);
    }
}

//! Configuration: precision formats, model architectures, device profiles,
//! and engine settings.
//!
//! Everything the paper parameterizes its evaluation over lives here: the
//! `WxAyKVz` precision notation (§1 footnote 1), the 16-model zoo (§5.1),
//! the four GPU profiles (§5.1), and the serving-engine knobs.

pub mod device;
pub mod engine;
pub mod model;
pub mod precision;

pub use device::{DeviceProfile, GpuArch};
pub use engine::{BackendKind, EngineConfig, LadderPolicy, PreemptionMode};
pub use model::{layer_importance, model_zoo, ModelConfig};
pub use precision::{DType, PrecisionFormat};

//! GPU device profiles for the four architectures the paper evaluates
//! (§5.1: RTX 4090, L40S, A100, H100).
//!
//! These numbers parameterize `gpusim` — the cost simulator substituted for
//! the CUDA testbed (DESIGN.md §1). All figures come from the public
//! datasheets; tensor-core numbers are *dense* (no sparsity marketing 2×).

/// GPU micro-architecture generation. Determines tensor-core MMA tile shapes
/// and which layouts MARLIN's static Ampere tuning matches (§2: MARLIN
/// "fails to adapt ... to GPU generations other than Ampere").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuArch {
    /// SM80/86 (A100, RTX 30xx).
    Ampere,
    /// SM89 (RTX 4090, L40S).
    Ada,
    /// SM90 (H100).
    Hopper,
}

impl GpuArch {
    /// Tensor-core MMA K extent for INT8 operands (16x8xK tiles; §3.3
    /// Challenge-V: 16x8x32 Ampere/Ada, 16x8x64 Hopper).
    pub const fn mma_k_int8(self) -> usize {
        match self {
            GpuArch::Ampere | GpuArch::Ada => 32,
            GpuArch::Hopper => 64,
        }
    }

    /// MMA K extent for FP16 operands (16x8x16 everywhere through Hopper).
    pub const fn mma_k_f16(self) -> usize {
        16
    }
}

/// Performance-relevant device parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub arch: GpuArch,
    /// HBM/GDDR peak bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Achievable fraction of peak bandwidth for well-coalesced streams.
    pub mem_eff: f64,
    /// Dense FP16 tensor-core throughput, FLOP/s.
    pub tc_f16_flops: f64,
    /// Dense INT8 tensor-core throughput, OP/s.
    pub tc_int8_ops: f64,
    /// FP8 tensor-core throughput, FLOP/s (0.0 when unsupported).
    pub tc_fp8_flops: f64,
    /// CUDA-core (ALU) FP32 throughput, FLOP/s — bounds I2F + FMA dequant.
    pub alu_f32_flops: f64,
    /// Shared memory bandwidth per SM, bytes/clock (128B/clk typical).
    pub smem_bytes_per_clk: f64,
    /// Number of SMs.
    pub sm_count: usize,
    /// Boost clock, Hz.
    pub clock_hz: f64,
    /// Global memory transaction segment size, bytes.
    pub segment_bytes: usize,
    /// Shared-memory banks (32 on every generation we model).
    pub smem_banks: usize,
    /// Device-memory capacity, bytes.
    pub mem_capacity: usize,
    /// Interconnect bandwidth for tensor parallelism, bytes/s per direction
    /// (NVLink for A100/H100; PCIe Gen4 for the workstation parts).
    pub interconnect_bw: f64,
    /// Kernel launch + runtime overhead per kernel, seconds.
    pub launch_overhead_s: f64,
}

const GIB: usize = 1 << 30;

impl DeviceProfile {
    pub fn rtx4090() -> Self {
        Self {
            name: "RTX4090",
            arch: GpuArch::Ada,
            mem_bw: 1.008e12,
            mem_eff: 0.86,
            tc_f16_flops: 165.2e12,
            tc_int8_ops: 330.3e12,
            tc_fp8_flops: 330.3e12,
            alu_f32_flops: 82.6e12,
            smem_bytes_per_clk: 128.0,
            sm_count: 128,
            clock_hz: 2.52e9,
            segment_bytes: 128,
            smem_banks: 32,
            mem_capacity: 24 * GIB,
            interconnect_bw: 32e9, // PCIe Gen4 x16
            launch_overhead_s: 4.0e-6,
        }
    }

    pub fn l40s() -> Self {
        Self {
            name: "L40S",
            arch: GpuArch::Ada,
            mem_bw: 0.864e12,
            mem_eff: 0.85,
            tc_f16_flops: 181.0e12,
            tc_int8_ops: 362.0e12,
            tc_fp8_flops: 362.0e12,
            alu_f32_flops: 91.6e12,
            smem_bytes_per_clk: 128.0,
            sm_count: 142,
            clock_hz: 2.52e9,
            segment_bytes: 128,
            smem_banks: 32,
            mem_capacity: 48 * GIB,
            interconnect_bw: 32e9,
            launch_overhead_s: 4.0e-6,
        }
    }

    pub fn a100() -> Self {
        Self {
            name: "A100",
            arch: GpuArch::Ampere,
            mem_bw: 1.555e12, // 40GB SXM variant lineage; 80GB is 2.0e12
            mem_eff: 0.88,
            tc_f16_flops: 312.0e12,
            tc_int8_ops: 624.0e12,
            tc_fp8_flops: 0.0, // no FP8 tensor cores on Ampere
            alu_f32_flops: 19.5e12,
            smem_bytes_per_clk: 128.0,
            sm_count: 108,
            clock_hz: 1.41e9,
            segment_bytes: 128,
            smem_banks: 32,
            mem_capacity: 80 * GIB,
            interconnect_bw: 300e9, // NVLink3 per direction
            launch_overhead_s: 3.5e-6,
        }
    }

    pub fn h100() -> Self {
        Self {
            name: "H100",
            arch: GpuArch::Hopper,
            mem_bw: 3.35e12,
            mem_eff: 0.90,
            tc_f16_flops: 989.4e12 / 2.0, // dense
            tc_int8_ops: 1978.9e12 / 2.0,
            tc_fp8_flops: 1978.9e12 / 2.0,
            alu_f32_flops: 66.9e12,
            smem_bytes_per_clk: 128.0,
            sm_count: 132,
            clock_hz: 1.98e9,
            segment_bytes: 128,
            smem_banks: 32,
            mem_capacity: 80 * GIB,
            interconnect_bw: 450e9, // NVLink4 per direction
            launch_overhead_s: 3.0e-6,
        }
    }

    /// All four evaluation GPUs in the paper's order.
    pub fn all() -> Vec<DeviceProfile> {
        vec![Self::rtx4090(), Self::l40s(), Self::a100(), Self::h100()]
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        Self::all().into_iter().find(|d| d.name.eq_ignore_ascii_case(name))
    }

    /// Compute-to-bandwidth ratio (FLOP per byte) — the roofline ridge point
    /// the paper's §3.2 references ("arithmetic intensity far below the
    /// GPU's compute-to-bandwidth ratio").
    pub fn ridge_point_f16(&self) -> f64 {
        self.tc_f16_flops / self.mem_bw
    }

    /// Tensor-core throughput for a given operand bit-width, OP/s.
    pub fn tc_ops_for_bits(&self, bits: usize) -> f64 {
        match bits {
            4 | 8 => self.tc_int8_ops, // INT4 MMA retired post-Ampere; use INT8 path
            16 => self.tc_f16_flops,
            _ => self.tc_f16_flops,
        }
    }

    /// Aggregate shared-memory bandwidth, bytes/s.
    pub fn smem_bw(&self) -> f64 {
        self.smem_bytes_per_clk * self.clock_hz * self.sm_count as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_profiles() {
        let all = DeviceProfile::all();
        assert_eq!(all.len(), 4);
        let names: Vec<_> = all.iter().map(|d| d.name).collect();
        assert_eq!(names, ["RTX4090", "L40S", "A100", "H100"]);
    }

    #[test]
    fn lookup_case_insensitive() {
        assert!(DeviceProfile::by_name("a100").is_some());
        assert!(DeviceProfile::by_name("H100").is_some());
        assert!(DeviceProfile::by_name("B200").is_none());
    }

    #[test]
    fn ridge_points_ordering() {
        // All modern GPUs have ridge points far above decode arithmetic
        // intensity (~1-2 FLOP/byte), which is the paper's premise.
        for d in DeviceProfile::all() {
            assert!(d.ridge_point_f16() > 100.0, "{}: {}", d.name, d.ridge_point_f16());
        }
    }

    #[test]
    fn mma_tiles_per_arch() {
        assert_eq!(GpuArch::Ampere.mma_k_int8(), 32);
        assert_eq!(GpuArch::Hopper.mma_k_int8(), 64);
        assert_eq!(GpuArch::Ada.mma_k_f16(), 16);
    }

    #[test]
    fn hopper_fastest() {
        let (a, h) = (DeviceProfile::a100(), DeviceProfile::h100());
        assert!(h.mem_bw > a.mem_bw);
        assert!(h.tc_f16_flops > a.tc_f16_flops);
        assert_eq!(DeviceProfile::a100().tc_fp8_flops, 0.0);
    }

    #[test]
    fn smem_bw_is_huge() {
        // Shared memory aggregate bandwidth dwarfs HBM — bank conflicts, not
        // raw capacity, are what matters (Challenge-II).
        for d in DeviceProfile::all() {
            assert!(d.smem_bw() > 5.0 * d.mem_bw, "{}", d.name);
        }
    }
}

//! Workload generation: Poisson arrivals over synthetic length
//! distributions matching the paper's §5.1 setup.
//!
//! * **ShareGPT-like chat** — log-normal prompt/generation lengths fitted
//!   to the published ShareGPT statistics (mean prompt ≈ 161 tokens, mean
//!   generation ≈ 338 tokens) used for the general serving figures.
//! * **Reasoning (NuminaMath / AIMO-style)** — short prompts with long
//!   chain-of-thought generations (QwQ workloads, Fig 16).
//! * Requests arrive by a Poisson process at a configurable rate, exactly
//!   the methodology the paper takes from AlpaServe/HexGen (§5.1).

use crate::util::rng::Rng;

/// One synthetic request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRequest {
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
}

/// Length distribution family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// ShareGPT-style chat (general serving figures).
    Chat,
    /// Mathematical reasoning (Fig 16 "math").
    ReasoningMath,
    /// AIMO validation (Fig 16 "validation").
    ReasoningValidation,
}

impl WorkloadKind {
    /// (prompt mu/sigma, gen mu/sigma) of the underlying log-normals, plus
    /// clamping bounds. Parameters chosen so the means match the published
    /// dataset statistics (see module docs).
    fn params(self) -> LenParams {
        match self {
            // ln-mean ≈ ln(161) - σ²/2 keeps E[x] ≈ 161 at σ = 0.9.
            WorkloadKind::Chat => LenParams {
                prompt_mu: 4.68,
                prompt_sigma: 0.9,
                gen_mu: 5.42,
                gen_sigma: 0.85,
                min_prompt: 4,
                max_prompt: 2048,
                min_gen: 8,
                max_gen: 2048,
            },
            // Short problem statements, long CoT generations.
            WorkloadKind::ReasoningMath => LenParams {
                prompt_mu: 4.6,
                prompt_sigma: 0.5,
                gen_mu: 7.0,
                gen_sigma: 0.6,
                min_prompt: 16,
                max_prompt: 512,
                min_gen: 256,
                max_gen: 8192,
            },
            WorkloadKind::ReasoningValidation => LenParams {
                prompt_mu: 5.0,
                prompt_sigma: 0.5,
                gen_mu: 6.6,
                gen_sigma: 0.5,
                min_prompt: 32,
                max_prompt: 768,
                min_gen: 128,
                max_gen: 4096,
            },
        }
    }
}

struct LenParams {
    prompt_mu: f64,
    prompt_sigma: f64,
    gen_mu: f64,
    gen_sigma: f64,
    min_prompt: usize,
    max_prompt: usize,
    min_gen: usize,
    max_gen: usize,
}

/// Trace generator.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub kind: WorkloadKind,
    /// Poisson arrival rate, requests/second.
    pub rate: f64,
    pub seed: u64,
}

impl WorkloadGen {
    pub fn new(kind: WorkloadKind, rate: f64, seed: u64) -> Self {
        Self { kind, rate, seed }
    }

    /// Generate `n` requests.
    pub fn generate(&self, n: usize) -> Vec<TraceRequest> {
        let p = self.kind.params();
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.exp_gap(self.rate);
                let prompt = (rng.lognormal(p.prompt_mu, p.prompt_sigma) as usize)
                    .clamp(p.min_prompt, p.max_prompt);
                let gen = (rng.lognormal(p.gen_mu, p.gen_sigma) as usize)
                    .clamp(p.min_gen, p.max_gen);
                TraceRequest { arrival_s: t, prompt_tokens: prompt, gen_tokens: gen }
            })
            .collect()
    }

    /// Generate with lengths rescaled to fit a smaller context (used to
    /// drive the tiny PJRT model with the same *shape* of distribution).
    pub fn generate_scaled(&self, n: usize, max_prompt: usize, max_gen: usize) -> Vec<TraceRequest> {
        self.generate(n)
            .into_iter()
            .map(|r| TraceRequest {
                arrival_s: r.arrival_s,
                prompt_tokens: (r.prompt_tokens * max_prompt / 2048).clamp(1, max_prompt),
                gen_tokens: (r.gen_tokens * max_gen / 2048).clamp(1, max_gen),
            })
            .collect()
    }

    /// Deterministic prompt token ids for a request (synthetic "content").
    pub fn prompt_tokens(&self, req_index: usize, len: usize, vocab: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.seed ^ (req_index as u64).wrapping_mul(0x9E3779B97F4A7C15));
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_poisson_at_rate() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 5.0, 1);
        let n = 20_000;
        let trace = g.generate(n);
        let total = trace.last().unwrap().arrival_s;
        let rate = n as f64 / total;
        assert!((rate - 5.0).abs() < 0.2, "rate {rate}");
        // Arrivals strictly increasing.
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn chat_lengths_match_sharegpt_stats() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 1.0, 2);
        let trace = g.generate(20_000);
        let pm: f64 =
            trace.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / trace.len() as f64;
        let gm: f64 =
            trace.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / trace.len() as f64;
        assert!((120.0..210.0).contains(&pm), "prompt mean {pm} (ShareGPT ≈ 161)");
        assert!((270.0..420.0).contains(&gm), "gen mean {gm} (ShareGPT ≈ 338)");
    }

    #[test]
    fn reasoning_has_long_generations() {
        let chat = WorkloadGen::new(WorkloadKind::Chat, 1.0, 3).generate(5000);
        let math = WorkloadGen::new(WorkloadKind::ReasoningMath, 1.0, 3).generate(5000);
        let mean = |t: &[TraceRequest]| {
            t.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / t.len() as f64
        };
        assert!(mean(&math) > 2.0 * mean(&chat), "math {} chat {}", mean(&math), mean(&chat));
        // And short prompts relative to their generations.
        let pmean =
            math.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / math.len() as f64;
        assert!(pmean < mean(&math) / 3.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadGen::new(WorkloadKind::Chat, 2.0, 9).generate(100);
        let b = WorkloadGen::new(WorkloadKind::Chat, 2.0, 9).generate(100);
        assert_eq!(a, b);
        let c = WorkloadGen::new(WorkloadKind::Chat, 2.0, 10).generate(100);
        assert_ne!(a, c);
    }

    #[test]
    fn scaled_lengths_fit_tiny_context() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 4.0, 5);
        for r in g.generate_scaled(2000, 128, 64) {
            assert!((1..=128).contains(&r.prompt_tokens));
            assert!((1..=64).contains(&r.gen_tokens));
        }
    }

    #[test]
    fn prompt_tokens_in_vocab_and_deterministic() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 1.0, 7);
        let a = g.prompt_tokens(3, 50, 2048);
        let b = g.prompt_tokens(3, 50, 2048);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..2048).contains(&t)));
        assert_ne!(a, g.prompt_tokens(4, 50, 2048));
    }
}

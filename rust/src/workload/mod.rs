//! Workload generation: Poisson arrivals over synthetic length
//! distributions matching the paper's §5.1 setup.
//!
//! * **ShareGPT-like chat** — log-normal prompt/generation lengths fitted
//!   to the published ShareGPT statistics (mean prompt ≈ 161 tokens, mean
//!   generation ≈ 338 tokens) used for the general serving figures.
//! * **Reasoning (NuminaMath / AIMO-style)** — short prompts with long
//!   chain-of-thought generations (QwQ workloads, Fig 16).
//! * Requests arrive by a Poisson process at a configurable rate, exactly
//!   the methodology the paper takes from AlpaServe/HexGen (§5.1).

use crate::util::rng::Rng;

/// One synthetic request in a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRequest {
    /// Arrival time, seconds from trace start.
    pub arrival_s: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Prefix-sharing group (0 = none): requests in the same group open
    /// with the same `prefix_tokens`-token prompt prefix, so a
    /// prefix-caching engine prefills it once. Used by the serving
    /// simulator's abstract cache model.
    pub prefix_group: u64,
    /// Shared-prefix length within `prefix_group`, tokens.
    pub prefix_tokens: usize,
}

/// Length distribution family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// ShareGPT-style chat (general serving figures).
    Chat,
    /// Mathematical reasoning (Fig 16 "math").
    ReasoningMath,
    /// AIMO validation (Fig 16 "validation").
    ReasoningValidation,
}

impl WorkloadKind {
    /// (prompt mu/sigma, gen mu/sigma) of the underlying log-normals, plus
    /// clamping bounds. Parameters chosen so the means match the published
    /// dataset statistics (see module docs).
    fn params(self) -> LenParams {
        match self {
            // ln-mean ≈ ln(161) - σ²/2 keeps E[x] ≈ 161 at σ = 0.9.
            WorkloadKind::Chat => LenParams {
                prompt_mu: 4.68,
                prompt_sigma: 0.9,
                gen_mu: 5.42,
                gen_sigma: 0.85,
                min_prompt: 4,
                max_prompt: 2048,
                min_gen: 8,
                max_gen: 2048,
            },
            // Short problem statements, long CoT generations.
            WorkloadKind::ReasoningMath => LenParams {
                prompt_mu: 4.6,
                prompt_sigma: 0.5,
                gen_mu: 7.0,
                gen_sigma: 0.6,
                min_prompt: 16,
                max_prompt: 512,
                min_gen: 256,
                max_gen: 8192,
            },
            WorkloadKind::ReasoningValidation => LenParams {
                prompt_mu: 5.0,
                prompt_sigma: 0.5,
                gen_mu: 6.6,
                gen_sigma: 0.5,
                min_prompt: 32,
                max_prompt: 768,
                min_gen: 128,
                max_gen: 4096,
            },
        }
    }
}

struct LenParams {
    prompt_mu: f64,
    prompt_sigma: f64,
    gen_mu: f64,
    gen_sigma: f64,
    min_prompt: usize,
    max_prompt: usize,
    min_gen: usize,
    max_gen: usize,
}

/// Trace generator.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub kind: WorkloadKind,
    /// Poisson arrival rate, requests/second.
    pub rate: f64,
    pub seed: u64,
}

impl WorkloadGen {
    pub fn new(kind: WorkloadKind, rate: f64, seed: u64) -> Self {
        Self { kind, rate, seed }
    }

    /// Generate `n` requests.
    pub fn generate(&self, n: usize) -> Vec<TraceRequest> {
        let p = self.kind.params();
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        (0..n)
            .map(|_| {
                t += rng.exp_gap(self.rate);
                let prompt = (rng.lognormal(p.prompt_mu, p.prompt_sigma) as usize)
                    .clamp(p.min_prompt, p.max_prompt);
                let gen = (rng.lognormal(p.gen_mu, p.gen_sigma) as usize)
                    .clamp(p.min_gen, p.max_gen);
                TraceRequest {
                    arrival_s: t,
                    prompt_tokens: prompt,
                    gen_tokens: gen,
                    prefix_group: 0,
                    prefix_tokens: 0,
                }
            })
            .collect()
    }

    /// Generate with lengths rescaled to fit a smaller context (used to
    /// drive the tiny PJRT model with the same *shape* of distribution).
    pub fn generate_scaled(&self, n: usize, max_prompt: usize, max_gen: usize) -> Vec<TraceRequest> {
        self.generate(n)
            .into_iter()
            .map(|r| TraceRequest {
                prompt_tokens: (r.prompt_tokens * max_prompt / 2048).clamp(1, max_prompt),
                gen_tokens: (r.gen_tokens * max_gen / 2048).clamp(1, max_gen),
                ..r
            })
            .collect()
    }

    /// Deterministic prompt token ids for a request (synthetic "content").
    pub fn prompt_tokens(&self, req_index: usize, len: usize, vocab: usize) -> Vec<i32> {
        let mut rng = Rng::new(self.seed ^ (req_index as u64).wrapping_mul(0x9E3779B97F4A7C15));
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }
}

/// Multi-turn chat over a shared system prompt — the ROADMAP's
/// million-user traffic shape and the scenario the prefix-sharing KV cache
/// exists for. Every request's prompt opens with the same
/// `shared_tokens`-token system + few-shot prefix; each user then holds a
/// conversation whose prompt grows by the running history (previous turns'
/// prompts and responses).
#[derive(Debug, Clone)]
pub struct SharedPrefixGen {
    /// Tokens of the common system prompt (shared across *all* users).
    pub shared_tokens: usize,
    /// Distinct users (concurrent conversations).
    pub users: usize,
    /// Turns per user.
    pub turns: usize,
    /// Fresh prompt tokens each user adds per turn.
    pub turn_tokens: usize,
    /// Response tokens generated per turn.
    pub gen_tokens: usize,
    /// Poisson arrival rate, requests/second.
    pub rate: f64,
    pub seed: u64,
}

impl SharedPrefixGen {
    /// Generate the `users × turns` trace: users interleave round-robin so
    /// a user's turn k+1 always arrives after its turn k. The advertised
    /// `prefix_group`/`prefix_tokens` claim only the *system prompt* — the
    /// conservative, content-safe assertion for the abstract simulator
    /// model; the engine's radix index additionally matches each user's
    /// growing history from the real token ids
    /// ([`SharedPrefixGen::prompt_tokens`]).
    pub fn generate(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.users * self.turns);
        for turn in 0..self.turns {
            for _user in 0..self.users {
                t += rng.exp_gap(self.rate);
                let history = turn * (self.turn_tokens + self.gen_tokens);
                out.push(TraceRequest {
                    arrival_s: t,
                    prompt_tokens: self.shared_tokens + history + self.turn_tokens,
                    gen_tokens: self.gen_tokens,
                    prefix_group: 1,
                    prefix_tokens: self.shared_tokens,
                });
            }
        }
        out
    }

    /// Deterministic token ids for trace request `req_index` (requests are
    /// ordered as [`SharedPrefixGen::generate`] emits them): the system
    /// prefix depends only on the seed — bit-identical across every user —
    /// and each user's history is drawn from one per-user stream, so a
    /// user's turn-k prompt is a strict prefix of its turn-(k+1) prompt.
    pub fn prompt_tokens(&self, req_index: usize, vocab: usize) -> Vec<i32> {
        let user = req_index % self.users;
        let turn = req_index / self.users;
        let mut toks = Vec::new();
        let mut sys = Rng::new(self.seed ^ 0x5957_EA11);
        for _ in 0..self.shared_tokens {
            toks.push(sys.below(vocab) as i32);
        }
        let mut hist =
            Rng::new(self.seed ^ (user as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let n = turn * (self.turn_tokens + self.gen_tokens) + self.turn_tokens;
        for _ in 0..n {
            toks.push(hist.below(vocab) as i32);
        }
        toks
    }
}

/// Multi-tenant traffic for the cluster tier: `tenants` independent
/// organizations, each with its **own** shared system prompt, each running
/// `users` concurrent multi-turn conversations ([`SharedPrefixGen`] is the
/// single-tenant special case). Requests advertise `prefix_group = tenant
/// + 1`, so a prefix-affinity router can keep a tenant's traffic — and
/// therefore its resident prefix blocks — on one replica, while spreading
/// tenants across the fleet.
#[derive(Debug, Clone)]
pub struct MultiTenantGen {
    /// Distinct tenants (each with its own shared system prompt).
    pub tenants: usize,
    /// Concurrent conversations per tenant.
    pub users: usize,
    /// Turns per conversation.
    pub turns: usize,
    /// Tokens of each tenant's system prompt.
    pub shared_tokens: usize,
    /// Fresh prompt tokens a user adds per turn.
    pub turn_tokens: usize,
    /// Response tokens generated per turn.
    pub gen_tokens: usize,
    /// Poisson arrival rate, requests/second (global across tenants).
    pub rate: f64,
    pub seed: u64,
}

impl MultiTenantGen {
    /// Generate the `tenants × users × turns` trace, turn-major then
    /// tenant then user, so every conversation's turn k arrives before its
    /// turn k+1 and tenants interleave the way independent traffic would.
    pub fn generate(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(self.tenants * self.users * self.turns);
        for turn in 0..self.turns {
            for tenant in 0..self.tenants {
                for _user in 0..self.users {
                    t += rng.exp_gap(self.rate);
                    let history = turn * (self.turn_tokens + self.gen_tokens);
                    out.push(TraceRequest {
                        arrival_s: t,
                        prompt_tokens: self.shared_tokens + history + self.turn_tokens,
                        gen_tokens: self.gen_tokens,
                        prefix_group: tenant as u64 + 1,
                        prefix_tokens: self.shared_tokens,
                    });
                }
            }
        }
        out
    }

    /// (tenant, user, turn) of trace request `req_index`, matching
    /// [`MultiTenantGen::generate`]'s emission order.
    pub fn locate(&self, req_index: usize) -> (usize, usize, usize) {
        let per_turn = self.tenants * self.users;
        let turn = req_index / per_turn;
        let rem = req_index % per_turn;
        (rem / self.users, rem % self.users, turn)
    }

    /// Deterministic token ids for trace request `req_index`: the system
    /// prefix depends only on (seed, tenant) — identical across a tenant's
    /// users, distinct across tenants — and each (tenant, user) history is
    /// one stream, so a conversation's turn-k prompt is a strict prefix of
    /// its turn-(k+1) prompt.
    pub fn prompt_tokens(&self, req_index: usize, vocab: usize) -> Vec<i32> {
        let (tenant, user, turn) = self.locate(req_index);
        let mut toks = Vec::new();
        let mut sys = Rng::new(
            self.seed ^ 0x7E4A_4700 ^ (tenant as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F),
        );
        for _ in 0..self.shared_tokens {
            toks.push(sys.below(vocab) as i32);
        }
        let mut hist = Rng::new(
            self.seed
                ^ ((tenant * self.users + user) as u64 + 1)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let n = turn * (self.turn_tokens + self.gen_tokens) + self.turn_tokens;
        for _ in 0..n {
            toks.push(hist.below(vocab) as i32);
        }
        toks
    }
}

/// Bursty overload traffic — the KV-pressure scenario the preemption
/// subsystem (DESIGN.md §8) exists for. Requests arrive in `bursts` waves
/// of `burst_size` near-simultaneous requests (jittered by a fast Poisson
/// process), `gap_s` apart; prompt and generation lengths are drawn
/// uniformly from `±25%` bands around the configured means. Against a pool
/// of `P` tokens, a wave of `burst_size × (prompt + gen)` tokens
/// oversubscribes it by [`BurstGen::oversubscription`] — size the pool so
/// that ratio is ~2× to reproduce the `bench preempt` regime.
#[derive(Debug, Clone)]
pub struct BurstGen {
    /// Number of arrival waves.
    pub bursts: usize,
    /// Requests per wave.
    pub burst_size: usize,
    /// Seconds between wave starts.
    pub gap_s: f64,
    /// Mean prompt length, tokens.
    pub prompt_tokens: usize,
    /// Mean generation length, tokens.
    pub gen_tokens: usize,
    pub seed: u64,
}

impl BurstGen {
    /// Generate the `bursts × burst_size` trace, wave-ordered; arrivals
    /// within a wave are jittered ~1 ms apart so they are strictly
    /// increasing (the scheduler sees them as one queue-filling spike).
    pub fn generate(&self) -> Vec<TraceRequest> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.bursts * self.burst_size);
        for b in 0..self.bursts {
            let mut t = b as f64 * self.gap_s;
            for _ in 0..self.burst_size {
                t += rng.exp_gap(1000.0);
                let jit = |mean: usize, rng: &mut Rng| {
                    let lo = (mean * 3 / 4).max(1);
                    let hi = (mean * 5 / 4).max(lo + 1);
                    rng.range(lo, hi)
                };
                out.push(TraceRequest {
                    arrival_s: t,
                    prompt_tokens: jit(self.prompt_tokens, &mut rng),
                    gen_tokens: jit(self.gen_tokens, &mut rng),
                    prefix_group: 0,
                    prefix_tokens: 0,
                });
            }
        }
        out
    }

    /// Peak pool pressure of one wave against a `pool_tokens`-token KV
    /// pool: total wave footprint / pool size (2.0 = the ISSUE's "2×
    /// oversubscribed" operating point, using the configured means).
    pub fn oversubscription(&self, pool_tokens: usize) -> f64 {
        (self.burst_size * (self.prompt_tokens + self.gen_tokens)) as f64
            / pool_tokens.max(1) as f64
    }

    /// Deterministic prompt token ids for trace request `req_index` —
    /// distinct per request (no shared prefixes; pressure, not reuse, is
    /// this generator's point).
    pub fn prompt_tokens(&self, req_index: usize, len: usize, vocab: usize) -> Vec<i32> {
        let mut rng =
            Rng::new(self.seed ^ (req_index as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
        (0..len).map(|_| rng.below(vocab) as i32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_poisson_at_rate() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 5.0, 1);
        let n = 20_000;
        let trace = g.generate(n);
        let total = trace.last().unwrap().arrival_s;
        let rate = n as f64 / total;
        assert!((rate - 5.0).abs() < 0.2, "rate {rate}");
        // Arrivals strictly increasing.
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    #[test]
    fn chat_lengths_match_sharegpt_stats() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 1.0, 2);
        let trace = g.generate(20_000);
        let pm: f64 =
            trace.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / trace.len() as f64;
        let gm: f64 =
            trace.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / trace.len() as f64;
        assert!((120.0..210.0).contains(&pm), "prompt mean {pm} (ShareGPT ≈ 161)");
        assert!((270.0..420.0).contains(&gm), "gen mean {gm} (ShareGPT ≈ 338)");
    }

    #[test]
    fn reasoning_has_long_generations() {
        let chat = WorkloadGen::new(WorkloadKind::Chat, 1.0, 3).generate(5000);
        let math = WorkloadGen::new(WorkloadKind::ReasoningMath, 1.0, 3).generate(5000);
        let mean = |t: &[TraceRequest]| {
            t.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / t.len() as f64
        };
        assert!(mean(&math) > 2.0 * mean(&chat), "math {} chat {}", mean(&math), mean(&chat));
        // And short prompts relative to their generations.
        let pmean =
            math.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / math.len() as f64;
        assert!(pmean < mean(&math) / 3.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WorkloadGen::new(WorkloadKind::Chat, 2.0, 9).generate(100);
        let b = WorkloadGen::new(WorkloadKind::Chat, 2.0, 9).generate(100);
        assert_eq!(a, b);
        let c = WorkloadGen::new(WorkloadKind::Chat, 2.0, 10).generate(100);
        assert_ne!(a, c);
    }

    #[test]
    fn scaled_lengths_fit_tiny_context() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 4.0, 5);
        for r in g.generate_scaled(2000, 128, 64) {
            assert!((1..=128).contains(&r.prompt_tokens));
            assert!((1..=64).contains(&r.gen_tokens));
        }
    }

    #[test]
    fn prompt_tokens_in_vocab_and_deterministic() {
        let g = WorkloadGen::new(WorkloadKind::Chat, 1.0, 7);
        let a = g.prompt_tokens(3, 50, 2048);
        let b = g.prompt_tokens(3, 50, 2048);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..2048).contains(&t)));
        assert_ne!(a, g.prompt_tokens(4, 50, 2048));
    }

    #[test]
    fn plain_workloads_advertise_no_shared_prefix() {
        for r in WorkloadGen::new(WorkloadKind::Chat, 2.0, 1).generate(50) {
            assert_eq!((r.prefix_group, r.prefix_tokens), (0, 0));
        }
    }

    fn bg() -> BurstGen {
        BurstGen {
            bursts: 3,
            burst_size: 6,
            gap_s: 2.0,
            prompt_tokens: 40,
            gen_tokens: 24,
            seed: 5,
        }
    }

    #[test]
    fn burst_trace_shape_and_determinism() {
        let g = bg();
        let trace = g.generate();
        assert_eq!(trace.len(), 18);
        assert_eq!(trace, g.generate(), "same seed, same trace");
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s, "arrivals strictly increasing");
        }
        // Lengths stay in the ±25% jitter bands and advertise no prefix.
        for r in &trace {
            assert!((30..=50).contains(&r.prompt_tokens), "{}", r.prompt_tokens);
            assert!((18..=30).contains(&r.gen_tokens), "{}", r.gen_tokens);
            assert_eq!((r.prefix_group, r.prefix_tokens), (0, 0));
        }
        // Waves are tight spikes separated by the configured gap: every
        // wave's span is tiny relative to gap_s.
        for b in 0..3 {
            let wave = &trace[b * 6..(b + 1) * 6];
            let span = wave.last().unwrap().arrival_s - wave.first().unwrap().arrival_s;
            assert!(span < 0.2, "wave {b} span {span}");
            assert!(wave.first().unwrap().arrival_s >= b as f64 * 2.0);
            assert!(wave.first().unwrap().arrival_s < b as f64 * 2.0 + 0.2);
        }
    }

    #[test]
    fn burst_oversubscription_math() {
        let g = bg(); // 6 × (40 + 24) = 384 tokens per wave
        assert!((g.oversubscription(192) - 2.0).abs() < 1e-12, "2× at a 192-token pool");
        assert!((g.oversubscription(384) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn burst_prompts_are_deterministic_distinct_and_in_vocab() {
        let g = bg();
        let a = g.prompt_tokens(0, 40, 2048);
        assert_eq!(a, g.prompt_tokens(0, 40, 2048));
        assert_ne!(a, g.prompt_tokens(1, 40, 2048), "no accidental shared prefixes");
        assert!(a.iter().all(|&t| (0..2048).contains(&t)));
    }

    fn sp() -> SharedPrefixGen {
        SharedPrefixGen {
            shared_tokens: 64,
            users: 3,
            turns: 4,
            turn_tokens: 8,
            gen_tokens: 6,
            rate: 5.0,
            seed: 11,
        }
    }

    #[test]
    fn shared_prefix_trace_shape() {
        let g = sp();
        let trace = g.generate();
        assert_eq!(trace.len(), 12);
        for (i, r) in trace.iter().enumerate() {
            let turn = i / g.users;
            assert_eq!(r.prompt_tokens, 64 + turn * (8 + 6) + 8);
            assert_eq!(r.gen_tokens, 6);
            assert_eq!((r.prefix_group, r.prefix_tokens), (1, 64));
        }
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
    }

    fn mt() -> MultiTenantGen {
        MultiTenantGen {
            tenants: 3,
            users: 2,
            turns: 3,
            shared_tokens: 32,
            turn_tokens: 8,
            gen_tokens: 4,
            rate: 10.0,
            seed: 21,
        }
    }

    #[test]
    fn multi_tenant_trace_shape() {
        let g = mt();
        let trace = g.generate();
        assert_eq!(trace.len(), 18);
        assert_eq!(trace, g.generate(), "deterministic per seed");
        for (i, r) in trace.iter().enumerate() {
            let (tenant, _user, turn) = g.locate(i);
            assert_eq!(r.prefix_group, tenant as u64 + 1);
            assert_eq!(r.prefix_tokens, 32);
            assert_eq!(r.prompt_tokens, 32 + turn * 12 + 8);
            assert_eq!(r.gen_tokens, 4);
        }
        for w in trace.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        // Turn-major: all of turn 0 (6 requests) precedes all of turn 1.
        assert_eq!(g.locate(5), (2, 1, 0));
        assert_eq!(g.locate(6), (0, 0, 1));
    }

    #[test]
    fn multi_tenant_prefixes_share_within_not_across_tenants() {
        let g = mt();
        // Tenant 0's two users (requests 0, 1) share the system prompt…
        let a = g.prompt_tokens(0, 2048);
        let b = g.prompt_tokens(1, 2048);
        assert_eq!(a[..32], b[..32], "same tenant, same system prompt");
        assert_ne!(a[32..], b[32..], "…but user histories diverge");
        // …tenant 1 (request 2) has a different system prompt.
        let c = g.prompt_tokens(2, 2048);
        assert_ne!(a[..32], c[..32], "tenants must not share prefixes");
        // A conversation's prompts grow by strict prefix extension:
        // request 6 is tenant 0, user 0, turn 1.
        let t1 = g.prompt_tokens(6, 2048);
        assert!(t1.len() > a.len());
        assert_eq!(t1[..a.len()], a[..]);
        // Lengths match the trace and ids stay in vocab.
        let trace = g.generate();
        for (i, r) in trace.iter().enumerate() {
            let toks = g.prompt_tokens(i, 2048);
            assert_eq!(toks.len(), r.prompt_tokens, "request {i}");
            assert!(toks.iter().all(|&t| (0..2048).contains(&t)));
        }
    }

    #[test]
    fn shared_prefix_tokens_really_share() {
        let g = sp();
        // Every request opens with the identical system prompt…
        let sys = g.prompt_tokens(0, 2048)[..64].to_vec();
        for i in 1..12 {
            assert_eq!(g.prompt_tokens(i, 2048)[..64], sys[..], "request {i}");
        }
        // …user 1's turn-0 prompt is a strict prefix of its turn-1 prompt…
        let t0 = g.prompt_tokens(1, 2048); // user 1, turn 0
        let t1 = g.prompt_tokens(1 + g.users, 2048); // user 1, turn 1
        assert!(t1.len() > t0.len());
        assert_eq!(t1[..t0.len()], t0[..]);
        // …while different users diverge right after the system prompt.
        let u2 = g.prompt_tokens(2, 2048);
        assert_ne!(t0[64..], u2[64..]);
        // Lengths match the trace, and all ids are in vocab.
        let trace = g.generate();
        for (i, r) in trace.iter().enumerate() {
            let toks = g.prompt_tokens(i, 2048);
            assert_eq!(toks.len(), r.prompt_tokens, "request {i}");
            assert!(toks.iter().all(|&t| (0..2048).contains(&t)));
        }
    }
}

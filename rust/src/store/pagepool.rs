//! Shared page-buffer pool: an `Arc`'d free-list that recycles the
//! page-aligned I/O buffers every store read and write stages through
//! (SpacetimeDB's `PagePool` idiom — allocation reuse on deserialize).
//!
//! Buffers are whole-page multiples, so a buffer retired by one extent is
//! almost always large enough for the next: in the steady state the store
//! performs zero I/O-buffer allocations. The pool is shared by every
//! replica holding the same [`PageFileStore`](super::PageFileStore) — the
//! host-global store means host-global buffer reuse too.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Allocation-reuse counters (surfaced through
/// [`StoreStats`](super::StoreStats) and `bench persist`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PagePoolStats {
    /// Buffers handed out by allocating fresh memory.
    pub created: usize,
    /// Buffers handed out by reusing a retired allocation.
    pub reused: usize,
    /// Buffers currently parked on the free-list.
    pub cached: usize,
}

#[derive(Debug)]
struct Inner {
    page_size: usize,
    /// Free-list cap: retired buffers beyond this are dropped instead of
    /// parked, bounding idle memory at `max_cached × largest extent`.
    max_cached: usize,
    free: Mutex<Vec<Vec<u8>>>,
    created: AtomicUsize,
    reused: AtomicUsize,
}

/// The shared pool. Cloning shares the same free-list (`Arc` semantics).
#[derive(Debug, Clone)]
pub struct PagePool {
    inner: Arc<Inner>,
}

impl PagePool {
    pub fn new(page_size: usize, max_cached: usize) -> Self {
        assert!(page_size.is_power_of_two(), "page size must be a power of two");
        Self {
            inner: Arc::new(Inner {
                page_size,
                max_cached,
                free: Mutex::new(Vec::new()),
                created: AtomicUsize::new(0),
                reused: AtomicUsize::new(0),
            }),
        }
    }

    pub fn page_size(&self) -> usize {
        self.inner.page_size
    }

    /// Bytes rounded up to a whole number of pages.
    pub fn rounded(&self, bytes: usize) -> usize {
        let ps = self.inner.page_size;
        bytes.div_ceil(ps).max(1) * ps
    }

    /// A zeroed buffer of at least `bytes`, page-rounded — reusing a
    /// retired allocation when one is large enough. Zeroing makes record
    /// padding deterministic, so byte-comparing two page files written by
    /// identical operation sequences is meaningful.
    pub fn take(&self, bytes: usize) -> Vec<u8> {
        let need = self.rounded(bytes);
        let reusable = {
            let mut free = self.inner.free.lock().expect("page pool lock");
            free.iter()
                .position(|b| b.capacity() >= need)
                .map(|i| free.swap_remove(i))
        };
        match reusable {
            Some(mut buf) => {
                self.inner.reused.fetch_add(1, Ordering::Relaxed);
                buf.clear();
                buf.resize(need, 0);
                buf
            }
            None => {
                self.inner.created.fetch_add(1, Ordering::Relaxed);
                vec![0u8; need]
            }
        }
    }

    /// Retire a buffer back to the free-list (dropped when the list is at
    /// its cap or the buffer is smaller than one page).
    pub fn put(&self, buf: Vec<u8>) {
        if buf.capacity() < self.inner.page_size {
            return;
        }
        let mut free = self.inner.free.lock().expect("page pool lock");
        if free.len() < self.inner.max_cached {
            free.push(buf);
        }
    }

    pub fn stats(&self) -> PagePoolStats {
        PagePoolStats {
            created: self.inner.created.load(Ordering::Relaxed),
            reused: self.inner.reused.load(Ordering::Relaxed),
            cached: self.inner.free.lock().expect("page pool lock").len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_rounds_to_pages_and_zeroes() {
        let p = PagePool::new(256, 4);
        let b = p.take(1);
        assert_eq!(b.len(), 256);
        let b2 = p.take(257);
        assert_eq!(b2.len(), 512);
        assert!(b2.iter().all(|&x| x == 0));
        assert_eq!(p.take(0).len(), 256, "zero-byte requests still get one page");
    }

    #[test]
    fn retired_buffers_are_reused_and_rezeroed() {
        let p = PagePool::new(256, 4);
        let mut b = p.take(512);
        b[0] = 0xAB;
        let cap = b.capacity();
        p.put(b);
        assert_eq!(p.stats().cached, 1);
        // A smaller request reuses the larger retired buffer, zeroed.
        let b2 = p.take(256);
        assert_eq!(b2.capacity(), cap);
        assert!(b2.iter().all(|&x| x == 0), "reused buffer must be zeroed");
        let s = p.stats();
        assert_eq!((s.created, s.reused, s.cached), (2, 1, 0));
    }

    #[test]
    fn free_list_is_bounded_and_shared_across_clones() {
        let p = PagePool::new(256, 2);
        let q = p.clone();
        for _ in 0..5 {
            q.put(vec![0u8; 256]);
        }
        assert_eq!(p.stats().cached, 2, "cap bounds the free-list");
        p.take(256);
        assert_eq!(q.stats().reused, 1, "clones share one free-list");
    }
}

//! Byte codecs for persisted store payloads: CRC-32 (IEEE), the
//! [`SeqSnapshot`] wire format, and the [`KvLayout`] registry format.
//!
//! Every decode is fail-closed: any length, tag, or geometry that does not
//! reconcile internally is a [`StoreError::Corrupt`], never a partially
//! decoded value. The snapshot codec is self-describing (geometry + layout
//! are inside the payload), so a recovered page can be validated without
//! consulting any other page.

use anyhow::Result;

use super::StoreError;
use crate::kvcache::pool::KvPrecision;
use crate::kvcache::{KvLayout, SeqSnapshot};

/// CRC-32 (IEEE 802.3, reflected 0xEDB88320) lookup table, built at
/// compile time — the checksum persisted pages carry (satellite: corrupt
/// pages must fail closed, never feed garbage KV).
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 of `data` (IEEE polynomial, the zlib/PNG variant).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn read_u64(buf: &[u8], at: usize) -> Result<u64, StoreError> {
    let end = at.checked_add(8).filter(|&e| e <= buf.len()).ok_or_else(|| {
        StoreError::corrupt("payload", at as u64, "u64 field runs past the payload end")
    })?;
    Ok(u64::from_le_bytes(buf[at..end].try_into().unwrap()))
}

pub(crate) fn read_u32(buf: &[u8], at: usize) -> Result<u32, StoreError> {
    let end = at.checked_add(4).filter(|&e| e <= buf.len()).ok_or_else(|| {
        StoreError::corrupt("payload", at as u64, "u32 field runs past the payload end")
    })?;
    Ok(u32::from_le_bytes(buf[at..end].try_into().unwrap()))
}

/// Precision wire tags are the human-readable bit widths, so a hex dump of
/// a page file reads `10 08 04` for a kv16/kv8/kv4 layout.
fn prec_tag(p: KvPrecision) -> u8 {
    match p {
        KvPrecision::F32 => 16,
        KvPrecision::Int8 => 8,
        KvPrecision::Int4 => 4,
    }
}

fn prec_from_tag(tag: u8) -> Result<KvPrecision, StoreError> {
    Ok(match tag {
        16 => KvPrecision::F32,
        8 => KvPrecision::Int8,
        4 => KvPrecision::Int4,
        other => {
            return Err(StoreError::corrupt(
                "layout",
                0,
                format!("unknown kv precision tag {other} (expected 16, 8, or 4)"),
            ))
        }
    })
}

/// Append `layout` in registry form: layer count then one tag byte per
/// layer.
pub fn encode_layout_into(out: &mut Vec<u8>, layout: &KvLayout) {
    push_u64(out, layout.n_layers() as u64);
    out.extend(layout.precs().iter().map(|&p| prec_tag(p)));
}

/// Decode a layout from `buf[at..]`; returns the layout and the bytes
/// consumed.
pub fn decode_layout_at(buf: &[u8], at: usize) -> Result<(KvLayout, usize), StoreError> {
    let n = read_u64(buf, at)? as usize;
    if n == 0 || n > 4096 {
        return Err(StoreError::corrupt(
            "layout",
            at as u64,
            format!("implausible layer count {n}"),
        ));
    }
    let start = at + 8;
    if start + n > buf.len() {
        return Err(StoreError::corrupt(
            "layout",
            at as u64,
            "per-layer precision tags run past the payload end",
        ));
    }
    let mut precs = Vec::with_capacity(n);
    for &tag in &buf[start..start + n] {
        precs.push(prec_from_tag(tag)?);
    }
    let layout = KvLayout::from_precs(precs)
        .map_err(|e| StoreError::corrupt("layout", at as u64, e.to_string()))?;
    Ok((layout, 8 + n))
}

/// Serialize one layout-tagged snapshot:
///
/// ```text
/// len u64 | kv_heads u64 | head_dim u64 | layout (n_layers u64 + tags)
/// | codes_len u64 | codes | scales_count u64 | scales (f32 LE each)
/// ```
pub fn encode_snapshot(s: &SeqSnapshot) -> Vec<u8> {
    let mut out =
        Vec::with_capacity(48 + s.layout.n_layers() + s.codes.len() + 4 * s.scales.len());
    push_u64(&mut out, s.len as u64);
    push_u64(&mut out, s.kv_heads as u64);
    push_u64(&mut out, s.head_dim as u64);
    encode_layout_into(&mut out, &s.layout);
    push_u64(&mut out, s.codes.len() as u64);
    out.extend_from_slice(&s.codes);
    push_u64(&mut out, s.scales.len() as u64);
    for f in &s.scales {
        out.extend_from_slice(&f.to_le_bytes());
    }
    out
}

/// Decode and fully validate a snapshot payload. Both vector lengths must
/// reconcile with the self-described geometry (`len × token_code_bytes`
/// codes, `len × L × 2 × Hkv` scales) and the buffer must hold exactly the
/// declared bytes — anything else is [`StoreError::Corrupt`].
pub fn decode_snapshot(buf: &[u8]) -> Result<SeqSnapshot, StoreError> {
    let len = read_u64(buf, 0)? as usize;
    let kv_heads = read_u64(buf, 8)? as usize;
    let head_dim = read_u64(buf, 16)? as usize;
    let (layout, lbytes) = decode_layout_at(buf, 24)?;
    let mut at = 24 + lbytes;
    let codes_len = read_u64(buf, at)? as usize;
    at += 8;
    let expect_codes = len
        .checked_mul(layout.token_code_bytes(kv_heads, head_dim))
        .ok_or_else(|| StoreError::corrupt("snapshot", 0, "code length overflows"))?;
    if codes_len != expect_codes {
        return Err(StoreError::corrupt(
            "snapshot",
            at as u64,
            format!(
                "codes length {codes_len} != {expect_codes} implied by geometry \
                 (len {len}, layout {layout})"
            ),
        ));
    }
    if at + codes_len > buf.len() {
        return Err(StoreError::corrupt("snapshot", at as u64, "codes run past the payload end"));
    }
    let codes = buf[at..at + codes_len].to_vec();
    at += codes_len;
    let scales_count = read_u64(buf, at)? as usize;
    at += 8;
    let expect_scales = len * layout.n_layers() * 2 * kv_heads;
    if scales_count != expect_scales {
        return Err(StoreError::corrupt(
            "snapshot",
            at as u64,
            format!("scale count {scales_count} != {expect_scales} implied by geometry"),
        ));
    }
    if at + 4 * scales_count != buf.len() {
        return Err(StoreError::corrupt(
            "snapshot",
            at as u64,
            format!(
                "payload is {} bytes, expected exactly {}",
                buf.len(),
                at + 4 * scales_count
            ),
        ));
    }
    let mut scales = Vec::with_capacity(scales_count);
    for i in 0..scales_count {
        let o = at + 4 * i;
        scales.push(f32::from_le_bytes(buf[o..o + 4].try_into().unwrap()));
    }
    Ok(SeqSnapshot { len, codes, scales, kv_heads, head_dim, layout })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // The canonical IEEE test vector.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    fn snap(len: usize) -> SeqSnapshot {
        let layout = KvLayout::parse("l0:kv16,l1:kv8,l2:kv4", 3).unwrap();
        let (kv_heads, head_dim) = (2, 8);
        let tcb = layout.token_code_bytes(kv_heads, head_dim);
        SeqSnapshot {
            len,
            codes: (0..len * tcb).map(|i| (i * 7 + 3) as u8).collect(),
            scales: (0..len * 3 * 2 * kv_heads).map(|i| i as f32 * 0.5).collect(),
            kv_heads,
            head_dim,
            layout,
        }
    }

    #[test]
    fn snapshot_roundtrips_byte_exactly() {
        let s = snap(5);
        let buf = encode_snapshot(&s);
        let back = decode_snapshot(&buf).unwrap();
        assert_eq!(back, s);
        // Zero-length snapshots round-trip too.
        let z = snap(0);
        assert_eq!(decode_snapshot(&encode_snapshot(&z)).unwrap(), z);
    }

    #[test]
    fn truncated_or_padded_payloads_fail_closed() {
        let buf = encode_snapshot(&snap(3));
        for cut in [0, 7, 24, buf.len() - 1] {
            assert!(decode_snapshot(&buf[..cut]).is_err(), "cut at {cut} must fail");
        }
        let mut padded = buf.clone();
        padded.push(0);
        assert!(decode_snapshot(&padded).is_err(), "trailing bytes must fail");
    }

    #[test]
    fn corrupted_geometry_fields_fail_closed() {
        let s = snap(3);
        let buf = encode_snapshot(&s);
        // Inflate the declared token count: code/scale lengths no longer
        // reconcile with the geometry.
        let mut bad = buf.clone();
        bad[0] = bad[0].wrapping_add(1);
        assert!(decode_snapshot(&bad).is_err());
        // Unknown precision tag inside the layout table.
        let mut bad = buf;
        bad[32] = 9;
        let err = decode_snapshot(&bad).unwrap_err();
        assert!(err.to_string().contains("precision tag"), "{err}");
    }
}

//! Tiered KV persistence: a page-file-backed store with a host-global
//! prefix cache and warm restart (DESIGN.md §14).
//!
//! The in-memory [`SwapStore`](crate::kvcache::SwapStore) is RAM-bounded,
//! serves only its owning replica, and dies with the process. This module
//! is the disk tier underneath it: a single page file (boxerdb-style
//! layout — `page_size` / `metadata_offset` / `first_page_offset`) holding
//! layout-tagged [`SeqSnapshot`](crate::kvcache::SeqSnapshot) extents,
//! each a checksummed page-aligned record, plus a metadata header page.
//! Because records are self-describing and CRC-guarded, a process can
//! reopen the file and recover every fully-committed record — sessions
//! *and* cached prefix blocks survive a bounce (warm restart), and
//! partially-written extents are quarantined, never served.
//!
//! On top of the record log sits a **host-global prefix store**: the
//! chain-hash prefix keys the per-replica index already uses (content ×
//! `KvLayout` fingerprint) resolve to on-disk pages, so every replica
//! sharing one [`PageFileStore`] shares one prefix cache — a tenant system
//! prompt is prefilled once per host, not once per replica. Replicas adopt
//! hits through the byte-exact `import_seq`/`transcode_to` path, which
//! also finally delivers the PR 5 warm-restore follow-up: a kv16 entry
//! published before the pool laddered down re-inflates into the narrower
//! pool bit-identically.
//!
//! All I/O buffers stage through a shared [`PagePool`] (SpacetimeDB
//! idiom: an `Arc`'d free-list with allocation reuse on deserialize).

mod codec;
mod pagefile;
mod pagepool;
mod prefix_store;

pub use codec::{crc32, decode_snapshot, encode_snapshot};
pub use pagefile::{PageFileStore, StoreReceipt, StoreStats};
pub use pagepool::{PagePool, PagePoolStats};
pub use prefix_store::{fetch_chain, resolve_shared_prefix, SharedPrefixHit};

use std::path::PathBuf;

/// Default page size, following boxerdb's `StorageConfig` default.
pub const DEFAULT_PAGE_SIZE: usize = 4096;

/// Page-file geometry + placement (the boxerdb `StorageConfig` shape).
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// The page file's path (created on first open).
    pub path: PathBuf,
    /// Fixed page size in bytes; records occupy whole pages. Power of two,
    /// ≥ 256.
    pub page_size: usize,
    /// Byte offset of the metadata region (the header page). Always 0 in
    /// the current format; kept explicit in the config so the on-disk
    /// layout is self-documenting.
    pub metadata_offset: u64,
    /// Byte offset of the first record page (one page past the metadata
    /// region).
    pub first_page_offset: u64,
    /// Capacity in record pages (0 = unbounded). Live records beyond this
    /// are rejected (snapshots) or make the prefix tier evict LRU entries.
    pub max_pages: usize,
}

impl StoreConfig {
    /// Default geometry at `path`: 4 KiB pages, header in page 0, records
    /// from page 1, unbounded.
    pub fn new(path: impl Into<PathBuf>) -> Self {
        Self::with_geometry(path, DEFAULT_PAGE_SIZE, 0)
    }

    /// Custom page size / capacity (the `--page-size` / `--store-pages`
    /// CLI knobs).
    pub fn with_geometry(path: impl Into<PathBuf>, page_size: usize, max_pages: usize) -> Self {
        Self {
            path: path.into(),
            page_size,
            metadata_offset: 0,
            first_page_offset: page_size as u64,
            max_pages,
        }
    }

    pub fn validate(&self) -> Result<(), StoreError> {
        if !self.page_size.is_power_of_two() || self.page_size < 256 {
            return Err(StoreError::Geometry(format!(
                "page size {} must be a power of two >= 256",
                self.page_size
            )));
        }
        if self.metadata_offset != 0 {
            return Err(StoreError::Geometry(format!(
                "metadata offset {} unsupported (format v1 pins it to 0)",
                self.metadata_offset
            )));
        }
        if self.first_page_offset != self.page_size as u64 {
            return Err(StoreError::Geometry(format!(
                "first page offset {} must equal the page size {}",
                self.first_page_offset, self.page_size
            )));
        }
        Ok(())
    }
}

/// Structured store failures. `Corrupt` is the fail-closed path: a page
/// whose checksum, magic, or self-described geometry does not reconcile is
/// reported — with where and why — and its bytes are never handed to a KV
/// pool.
#[derive(Debug)]
pub enum StoreError {
    /// A persisted page failed validation (CRC mismatch, bad magic, or
    /// geometry that does not reconcile with its own header).
    Corrupt {
        /// What was being validated (`"header"`, `"payload"`, …).
        what: &'static str,
        /// Byte offset in the page file (0 when not file-backed, e.g. a
        /// payload decoded from memory).
        offset: u64,
        detail: String,
    },
    /// The store is at `max_pages` and nothing evictable can make room.
    Full { needed_pages: usize, free_pages: usize },
    /// Invalid configuration or a geometry mismatch against an existing
    /// file (e.g. reopening with a different page size).
    Geometry(String),
    /// Underlying file I/O failure.
    Io(std::io::Error),
}

impl StoreError {
    pub(crate) fn corrupt(what: &'static str, offset: u64, detail: impl Into<String>) -> Self {
        StoreError::Corrupt { what, offset, detail: detail.into() }
    }

    /// Whether this is the fail-closed corruption arm (the negative tests
    /// assert on this rather than on message text).
    pub fn is_corrupt(&self) -> bool {
        matches!(self, StoreError::Corrupt { .. })
    }
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Corrupt { what, offset, detail } => {
                write!(f, "store corrupt {what} at byte {offset}: {detail}")
            }
            StoreError::Full { needed_pages, free_pages } => write!(
                f,
                "store full: need {needed_pages} pages, {free_pages} free"
            ),
            StoreError::Geometry(d) => write!(f, "store geometry: {d}"),
            StoreError::Io(e) => write!(f, "store io: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

//! The page file: a checksummed, page-aligned record log with scan
//! recovery (DESIGN.md §14).
//!
//! ## On-disk format (v1)
//!
//! ```text
//! page 0 (metadata region, boxerdb StorageConfig shape):
//!   0..8   file magic  "TMKVPGF1"
//!   8..12  format version u32 = 1
//!   12..16 page_size u32
//!   16..24 metadata_offset u64  (= 0)
//!   24..32 first_page_offset u64 (= page_size)
//!   32..36 crc32 of bytes 0..32
//!
//! pages 1.. (record log): page-aligned extents, each
//!   0..4   record magic "TKVR"
//!   4      kind u8   (1 snapshot, 2 prefix entry, 3 layout reg, 4 free)
//!   5      version u8 = 1
//!   6..8   reserved u16 = 0
//!   8..16  seq u64   (monotonic write order; highest seq wins a key)
//!   16..24 key_a u64 (snapshot: namespace | prefix: chain key | layout: root)
//!   24..32 key_b u64 (snapshot: id        | prefix: root key  | layout: block_tokens)
//!   32..40 payload_len u64
//!   40..44 crc32(payload)
//!   44..48 crc32(header bytes 0..44)
//!   48..   payload, zero-padded to the next page boundary
//! ```
//!
//! ## Recovery protocol
//!
//! Reopen scans the log sequentially from `first_page_offset`. A page
//! whose header fails magic/CRC validation, or whose payload is cut by the
//! file end or fails its payload CRC, is **quarantined** (counted, its
//! pages returned to the free list, its bytes never served). Valid records
//! are applied in `seq` order, so when a crash leaves both an old and a
//! new extent for the same key (an interrupted overwrite), the highest
//! sequence number wins and the loser's extent is freed. Deletion
//! overwrites the victim's header with a `free` record in place — the
//! header is destroyed, so a deleted record can never resurrect on
//! replay.

use std::collections::{BTreeMap, HashMap};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::codec::{crc32, decode_layout_at, decode_snapshot, encode_layout_into, encode_snapshot};
use super::pagepool::{PagePool, PagePoolStats};
use super::{StoreConfig, StoreError};
use crate::kvcache::prefix::layout_root_key;
use crate::kvcache::{KvLayout, SeqSnapshot};

const FILE_MAGIC: &[u8; 8] = b"TMKVPGF1";
const FORMAT_VERSION: u32 = 1;
const RECORD_MAGIC: u32 = u32::from_le_bytes(*b"TKVR");
const RECORD_VERSION: u8 = 1;
/// Fixed record header size, well under the minimum page.
pub(crate) const HEADER_BYTES: usize = 48;

const KIND_SNAPSHOT: u8 = 1;
const KIND_PREFIX: u8 = 2;
const KIND_LAYOUT: u8 = 3;
const KIND_FREE: u8 = 4;

/// What one store operation moved — the engine prices its modeled disk
/// clock and emits `StoreWrite`/`StoreRead` trace events from this.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreReceipt {
    /// Whole pages the record occupies on disk.
    pub pages: usize,
    /// Payload bytes (header and page padding excluded).
    pub payload_bytes: usize,
    /// Snapshot wire bytes (codes + f32 scales) split per precision rung
    /// of the snapshot's recorded layout — sums to `snapshot_bytes`, the
    /// same attribution rule swap/migration transfers use.
    pub bytes_by_rung: [usize; 3],
}

impl StoreReceipt {
    fn for_snapshot(snap: &SeqSnapshot, pages: usize, payload_bytes: usize) -> Self {
        Self { pages, payload_bytes, bytes_by_rung: snap.bytes_by_rung() }
    }

    /// Total attributed snapshot bytes.
    pub fn snapshot_bytes(&self) -> usize {
        self.bytes_by_rung.iter().sum()
    }

    /// Fold another receipt in (per-chunk aggregation of prefix
    /// publishes/fetches).
    pub fn merge(&mut self, other: &StoreReceipt) {
        self.pages += other.pages;
        self.payload_bytes += other.payload_bytes;
        for (a, b) in self.bytes_by_rung.iter_mut().zip(other.bytes_by_rung) {
            *a += b;
        }
    }
}

/// Store effectiveness + durability counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StoreStats {
    /// Live session snapshots.
    pub snapshots: usize,
    /// Live prefix blocks.
    pub prefix_blocks: usize,
    /// Registered layout roots.
    pub layouts: usize,
    /// Pages held by live records.
    pub used_pages: usize,
    /// Page capacity (0 = unbounded).
    pub capacity_pages: usize,
    /// Record writes (snapshots + prefix publishes + layout registrations).
    pub writes: usize,
    /// Record reads served (snapshot gets + prefix fetches).
    pub reads: usize,
    /// Records deleted (snapshot takes/drops + prefix evictions).
    pub deletes: usize,
    /// Padded bytes written to the file.
    pub write_bytes: usize,
    /// Padded bytes read from the file.
    pub read_bytes: usize,
    /// Live snapshot+prefix payload (codes + scales) per precision rung of
    /// each record's recorded layout — the on-disk byte table `bench
    /// persist` reports (kv4's 4× shrink is visible here).
    pub on_disk_bytes_by_rung: [usize; 3],
    /// Snapshots recovered live by the last reopen.
    pub recovered_snapshots: usize,
    /// Prefix blocks recovered live by the last reopen.
    pub recovered_prefix_blocks: usize,
    /// Pages quarantined by the last reopen (invalid header, cut payload,
    /// or CRC mismatch) — their bytes are never served.
    pub quarantined_pages: usize,
    /// Prefix blocks published (first writes, not republish no-ops).
    pub prefix_publishes: usize,
    /// Prefix blocks evicted to make room (LRU, leaves capacity to
    /// snapshots first).
    pub prefix_evicted: usize,
    /// Writes rejected because the store was full and nothing evictable
    /// could make room.
    pub rejected_full: usize,
}

impl StoreStats {
    /// Total live on-disk snapshot payload bytes.
    pub fn on_disk_bytes(&self) -> usize {
        self.on_disk_bytes_by_rung.iter().sum()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Extent {
    offset: u64,
    pages: u64,
}

#[derive(Debug, Clone, Copy)]
struct RecordMeta {
    extent: Extent,
    payload_len: usize,
    seq: u64,
    /// Tokens in the snapshot (swap backends size restores from this
    /// without touching the disk).
    tokens: usize,
    bytes_by_rung: [usize; 3],
}

#[derive(Debug, Clone, Copy)]
struct PrefixMeta {
    meta: RecordMeta,
    root: u64,
    last_used: u64,
}

#[derive(Debug)]
struct Inner {
    file: File,
    /// Append cursor: page-aligned end of the record log.
    end: u64,
    next_seq: u64,
    /// Free extents, sorted by offset, adjacent runs coalesced.
    free: Vec<Extent>,
    snaps: HashMap<(u64, u64), RecordMeta>,
    prefixes: HashMap<u64, PrefixMeta>,
    /// Root key → (layout, block_tokens). BTreeMap so every iteration
    /// order — and therefore every adoption tie-break — is deterministic.
    layouts: BTreeMap<u64, (KvLayout, usize)>,
    clock: u64,
    stats: StoreStats,
}

/// The page-file-backed KV store. One instance per host path; replicas
/// share it through `Arc` (every method takes `&self`).
#[derive(Debug)]
pub struct PageFileStore {
    cfg: StoreConfig,
    pool: PagePool,
    next_ns: AtomicU64,
    inner: Mutex<Inner>,
}

fn read_exact_at(file: &File, offset: u64, buf: &mut [u8]) -> Result<(), StoreError> {
    let mut f = file;
    f.seek(SeekFrom::Start(offset))?;
    f.read_exact(buf)?;
    Ok(())
}

fn write_all_at(file: &File, offset: u64, buf: &[u8]) -> Result<(), StoreError> {
    let mut f = file;
    f.seek(SeekFrom::Start(offset))?;
    f.write_all(buf)?;
    Ok(())
}

/// Parsed record header (validation already passed).
struct Header {
    kind: u8,
    seq: u64,
    key_a: u64,
    key_b: u64,
    payload_len: usize,
    payload_crc: u32,
}

fn encode_header(h: &Header) -> [u8; HEADER_BYTES] {
    let mut b = [0u8; HEADER_BYTES];
    b[0..4].copy_from_slice(&RECORD_MAGIC.to_le_bytes());
    b[4] = h.kind;
    b[5] = RECORD_VERSION;
    b[8..16].copy_from_slice(&h.seq.to_le_bytes());
    b[16..24].copy_from_slice(&h.key_a.to_le_bytes());
    b[24..32].copy_from_slice(&h.key_b.to_le_bytes());
    b[32..40].copy_from_slice(&(h.payload_len as u64).to_le_bytes());
    b[40..44].copy_from_slice(&h.payload_crc.to_le_bytes());
    let crc = crc32(&b[0..44]);
    b[44..48].copy_from_slice(&crc.to_le_bytes());
    b
}

fn decode_header(b: &[u8], offset: u64) -> Result<Header, StoreError> {
    debug_assert!(b.len() >= HEADER_BYTES);
    let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
    if magic != RECORD_MAGIC {
        return Err(StoreError::corrupt("header", offset, "bad record magic"));
    }
    let stored = u32::from_le_bytes(b[44..48].try_into().unwrap());
    if crc32(&b[0..44]) != stored {
        return Err(StoreError::corrupt("header", offset, "header crc mismatch"));
    }
    let kind = b[4];
    if !(KIND_SNAPSHOT..=KIND_FREE).contains(&kind) {
        return Err(StoreError::corrupt("header", offset, format!("unknown kind {kind}")));
    }
    if b[5] != RECORD_VERSION {
        return Err(StoreError::corrupt(
            "header",
            offset,
            format!("unsupported record version {}", b[5]),
        ));
    }
    Ok(Header {
        kind,
        seq: u64::from_le_bytes(b[8..16].try_into().unwrap()),
        key_a: u64::from_le_bytes(b[16..24].try_into().unwrap()),
        key_b: u64::from_le_bytes(b[24..32].try_into().unwrap()),
        payload_len: u64::from_le_bytes(b[32..40].try_into().unwrap()) as usize,
        payload_crc: u32::from_le_bytes(b[40..44].try_into().unwrap()),
    })
}

/// One valid record found by the recovery scan, pre-application.
struct ScanRec {
    header: Header,
    extent: Extent,
    /// Decoded light metadata for snapshot/prefix payloads.
    tokens: usize,
    bytes_by_rung: [usize; 3],
    /// Decoded layout for `KIND_LAYOUT` records.
    layout: Option<(KvLayout, usize)>,
}

impl Inner {
    fn pages_of(&self, bytes: usize, ps: u64) -> u64 {
        ((bytes as u64) + ps - 1) / ps
    }

    fn used_pages(&self) -> u64 {
        let snaps: u64 = self.snaps.values().map(|m| m.extent.pages).sum();
        let prefixes: u64 = self.prefixes.values().map(|p| p.meta.extent.pages).sum();
        // Layout registrations are one page each and never freed.
        snaps + prefixes + self.layouts.len() as u64
    }

    /// Whether `pages` more live pages fit under `max_pages` (0 =
    /// unbounded).
    fn has_room(&self, pages: u64, max_pages: usize) -> bool {
        max_pages == 0 || self.used_pages() + pages <= max_pages as u64
    }

    /// Insert a free extent, keeping the list offset-sorted and coalesced.
    fn release_extent(&mut self, e: Extent, ps: u64) {
        let i = self.free.partition_point(|f| f.offset < e.offset);
        self.free.insert(i, e);
        // Coalesce with neighbours.
        if i + 1 < self.free.len()
            && self.free[i].offset + self.free[i].pages * ps == self.free[i + 1].offset
        {
            self.free[i].pages += self.free[i + 1].pages;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].offset + self.free[i - 1].pages * ps == self.free[i].offset {
            self.free[i - 1].pages += self.free[i].pages;
            self.free.remove(i);
        }
    }

    /// First-fit extent for `pages`, splitting a larger free run (the
    /// remainder gets its own free marker written by the caller) or
    /// appending at the end of the log.
    fn alloc_extent(&mut self, pages: u64, ps: u64) -> (Extent, Option<Extent>) {
        if let Some(i) = self.free.iter().position(|f| f.pages >= pages) {
            let run = self.free.remove(i);
            let got = Extent { offset: run.offset, pages };
            let rest = (run.pages > pages)
                .then(|| Extent { offset: run.offset + pages * ps, pages: run.pages - pages });
            return (got, rest);
        }
        let got = Extent { offset: self.end, pages };
        self.end += pages * ps;
        (got, None)
    }

    /// Overwrite an extent's header with a `free` record in place: the old
    /// header is destroyed (no resurrection on replay) and the scanner can
    /// skip the extent in one hop.
    fn free_record(&mut self, e: Extent, ps: u64) -> Result<(), StoreError> {
        let h = Header {
            kind: KIND_FREE,
            seq: self.next_seq,
            key_a: 0,
            key_b: 0,
            payload_len: (e.pages * ps) as usize - HEADER_BYTES,
            payload_crc: 0,
        };
        self.next_seq += 1;
        write_all_at(&self.file, e.offset, &encode_header(&h))?;
        self.release_extent(e, ps);
        Ok(())
    }

    /// Write one record into `extent` through a pooled buffer.
    fn write_record(
        &mut self,
        pool: &PagePool,
        extent: Extent,
        kind: u8,
        key_a: u64,
        key_b: u64,
        payload: &[u8],
        ps: u64,
    ) -> Result<u64, StoreError> {
        let seq = self.next_seq;
        self.next_seq += 1;
        let h = Header {
            kind,
            seq,
            key_a,
            key_b,
            payload_len: payload.len(),
            payload_crc: crc32(payload),
        };
        let bytes = (extent.pages * ps) as usize;
        let mut buf = pool.take(bytes);
        buf[0..HEADER_BYTES].copy_from_slice(&encode_header(&h));
        buf[HEADER_BYTES..HEADER_BYTES + payload.len()].copy_from_slice(payload);
        write_all_at(&self.file, extent.offset, &buf)?;
        pool.put(buf);
        self.stats.writes += 1;
        self.stats.write_bytes += bytes;
        Ok(seq)
    }

    /// Read a record's payload back, re-validating header and payload CRCs
    /// against the bytes on disk — the fail-closed read path.
    fn read_payload(
        &mut self,
        pool: &PagePool,
        meta: &RecordMeta,
        kind: u8,
        key_a: u64,
        key_b: u64,
        ps: u64,
    ) -> Result<Vec<u8>, StoreError> {
        let bytes = HEADER_BYTES + meta.payload_len;
        let mut buf = pool.take(bytes);
        let take = bytes.min(buf.len());
        read_exact_at(&self.file, meta.extent.offset, &mut buf[..take])?;
        let h = decode_header(&buf, meta.extent.offset)?;
        if h.kind != kind || h.key_a != key_a || h.key_b != key_b || h.seq != meta.seq {
            pool.put(buf);
            return Err(StoreError::corrupt(
                "header",
                meta.extent.offset,
                "record header does not match the index entry",
            ));
        }
        if h.payload_len != meta.payload_len {
            pool.put(buf);
            return Err(StoreError::corrupt(
                "header",
                meta.extent.offset,
                "record length does not match the index entry",
            ));
        }
        let payload = buf[HEADER_BYTES..HEADER_BYTES + h.payload_len].to_vec();
        if crc32(&payload) != h.payload_crc {
            pool.put(buf);
            return Err(StoreError::corrupt(
                "payload",
                meta.extent.offset,
                "payload crc mismatch",
            ));
        }
        pool.put(buf);
        self.stats.reads += 1;
        self.stats.read_bytes += (meta.extent.pages * ps) as usize;
        Ok(payload)
    }
}

impl PageFileStore {
    /// Open (or create) the page file at `cfg.path`, recovering every
    /// fully-committed record and quarantining everything else.
    pub fn open(cfg: StoreConfig) -> Result<Arc<Self>, StoreError> {
        cfg.validate()?;
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(&cfg.path)?;
        let ps = cfg.page_size as u64;
        let pool = PagePool::new(cfg.page_size, 16);
        let file_len = file.metadata()?.len();
        let mut inner = Inner {
            file,
            end: ps,
            next_seq: 1,
            free: Vec::new(),
            snaps: HashMap::new(),
            prefixes: HashMap::new(),
            layouts: BTreeMap::new(),
            clock: 0,
            stats: StoreStats { capacity_pages: cfg.max_pages, ..StoreStats::default() },
        };
        if file_len == 0 {
            let mut page = pool.take(cfg.page_size);
            page[0..8].copy_from_slice(FILE_MAGIC);
            page[8..12].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
            page[12..16].copy_from_slice(&(cfg.page_size as u32).to_le_bytes());
            page[16..24].copy_from_slice(&cfg.metadata_offset.to_le_bytes());
            page[24..32].copy_from_slice(&cfg.first_page_offset.to_le_bytes());
            let crc = crc32(&page[0..32]);
            page[32..36].copy_from_slice(&crc.to_le_bytes());
            write_all_at(&inner.file, 0, &page)?;
            pool.put(page);
        } else {
            let mut head = [0u8; 36];
            if file_len < 36 {
                return Err(StoreError::corrupt("header", 0, "file shorter than its header"));
            }
            read_exact_at(&inner.file, 0, &mut head)?;
            if &head[0..8] != FILE_MAGIC {
                return Err(StoreError::corrupt("header", 0, "bad file magic"));
            }
            let stored = u32::from_le_bytes(head[32..36].try_into().unwrap());
            if crc32(&head[0..32]) != stored {
                return Err(StoreError::corrupt("header", 0, "file header crc mismatch"));
            }
            let ver = u32::from_le_bytes(head[8..12].try_into().unwrap());
            if ver != FORMAT_VERSION {
                return Err(StoreError::Geometry(format!("unsupported format version {ver}")));
            }
            let file_ps = u32::from_le_bytes(head[12..16].try_into().unwrap()) as usize;
            if file_ps != cfg.page_size {
                return Err(StoreError::Geometry(format!(
                    "file was written with {file_ps}-byte pages, reopened with {}",
                    cfg.page_size
                )));
            }
            Self::recover(&mut inner, &pool, file_len, ps)?;
        }
        let max_ns = inner.snaps.keys().map(|&(ns, _)| ns).max().unwrap_or(0);
        Ok(Arc::new(Self { cfg, pool, next_ns: AtomicU64::new(max_ns + 1), inner: Mutex::new(inner) }))
    }

    /// The recovery scan (see the module docs for the protocol).
    fn recover(
        inner: &mut Inner,
        pool: &PagePool,
        file_len: u64,
        ps: u64,
    ) -> Result<(), StoreError> {
        let mut offset = ps;
        let mut found: Vec<ScanRec> = Vec::new();
        let mut quarantined_pages = 0usize;
        while offset < file_len {
            if file_len - offset < HEADER_BYTES as u64 {
                // A cut tail shorter than one header: quarantine it.
                quarantined_pages += 1;
                break;
            }
            let mut hbuf = [0u8; HEADER_BYTES];
            read_exact_at(&inner.file, offset, &mut hbuf)?;
            let header = match decode_header(&hbuf, offset) {
                Ok(h) => h,
                Err(_) => {
                    // Unparseable page: quarantine it, keep scanning at
                    // the next page boundary (its space is reusable —
                    // anything written there is overwritten whole).
                    quarantined_pages += 1;
                    inner.release_extent(Extent { offset, pages: 1 }, ps);
                    offset += ps;
                    continue;
                }
            };
            let extent_bytes = ((HEADER_BYTES + header.payload_len) as u64 + ps - 1) / ps * ps;
            let pages = extent_bytes / ps;
            if header.kind == KIND_FREE {
                let present = (file_len - offset).min(extent_bytes) / ps;
                inner.release_extent(Extent { offset, pages: present.max(1) }, ps);
                inner.next_seq = inner.next_seq.max(header.seq + 1);
                offset += extent_bytes;
                continue;
            }
            if offset + (HEADER_BYTES + header.payload_len) as u64 > file_len {
                // Truncated mid-extent (the crash-recovery case): every
                // page the record would span that still exists is
                // quarantined; nothing can follow it.
                quarantined_pages += ((file_len - offset + ps - 1) / ps) as usize;
                break;
            }
            let mut buf = pool.take(HEADER_BYTES + header.payload_len);
            let take = HEADER_BYTES + header.payload_len;
            read_exact_at(&inner.file, offset, &mut buf[..take])?;
            let payload = &buf[HEADER_BYTES..HEADER_BYTES + header.payload_len];
            let valid = crc32(payload) == header.payload_crc;
            let rec = if !valid {
                None
            } else {
                match header.kind {
                    KIND_SNAPSHOT | KIND_PREFIX => decode_snapshot(payload).ok().map(|s| ScanRec {
                        tokens: s.len,
                        bytes_by_rung: s.bytes_by_rung(),
                        layout: None,
                        extent: Extent { offset, pages },
                        header,
                    }),
                    KIND_LAYOUT => decode_layout_at(payload, 0).ok().and_then(|(l, used)| {
                        (used == payload.len()).then(|| ScanRec {
                            tokens: 0,
                            bytes_by_rung: [0; 3],
                            layout: Some((l, 0)),
                            extent: Extent { offset, pages },
                            header,
                        })
                    }),
                    _ => unreachable!("kind validated by decode_header"),
                }
            };
            pool.put(buf);
            match rec {
                Some(r) => {
                    inner.next_seq = inner.next_seq.max(r.header.seq + 1);
                    found.push(r);
                }
                None => {
                    quarantined_pages += pages as usize;
                    inner.release_extent(Extent { offset, pages }, ps);
                }
            }
            offset += extent_bytes;
        }
        inner.end = offset.min(file_len / ps * ps).max(ps);

        // Apply in write order: the highest sequence number wins a key,
        // the loser's extent is freed.
        found.sort_by_key(|r| r.header.seq);
        for r in found {
            let meta = RecordMeta {
                extent: r.extent,
                payload_len: r.header.payload_len,
                seq: r.header.seq,
                tokens: r.tokens,
                bytes_by_rung: r.bytes_by_rung,
            };
            match r.header.kind {
                KIND_SNAPSHOT => {
                    if let Some(old) = inner.snaps.insert((r.header.key_a, r.header.key_b), meta) {
                        inner.free_record(old.extent, ps)?;
                    }
                }
                KIND_PREFIX => {
                    inner.clock += 1;
                    let pm = PrefixMeta { meta, root: r.header.key_b, last_used: inner.clock };
                    if let Some(old) = inner.prefixes.insert(r.header.key_a, pm) {
                        inner.free_record(old.meta.extent, ps)?;
                    }
                }
                KIND_LAYOUT => {
                    let (layout, _) = r.layout.expect("layout records carry a layout");
                    inner.layouts.insert(r.header.key_a, (layout, r.header.key_b as usize));
                }
                _ => unreachable!(),
            }
        }
        inner.stats.recovered_snapshots = inner.snaps.len();
        inner.stats.recovered_prefix_blocks = inner.prefixes.len();
        inner.stats.quarantined_pages = quarantined_pages;
        Ok(())
    }

    pub fn page_size(&self) -> usize {
        self.cfg.page_size
    }

    pub fn path(&self) -> &Path {
        &self.cfg.path
    }

    /// Pages a payload of `bytes` would occupy.
    pub fn pages_for(&self, bytes: usize) -> usize {
        (HEADER_BYTES + bytes).div_ceil(self.cfg.page_size)
    }

    /// Allocate a fresh snapshot namespace. Each engine sharing the store
    /// namespaces its request ids so replicas never collide; recovery
    /// seeds the counter above every persisted namespace, so a warm
    /// restart cannot collide with pre-crash sessions either.
    pub fn alloc_namespace(&self) -> u64 {
        self.next_ns.fetch_add(1, Ordering::Relaxed)
    }

    /// Whether `pages` more live pages fit.
    pub fn has_room(&self, pages: usize) -> bool {
        self.inner.lock().expect("store lock").has_room(pages as u64, self.cfg.max_pages)
    }

    /// Persist one session snapshot under `(ns, id)`, replacing any
    /// previous version. Fails with [`StoreError::Full`] when the capacity
    /// budget cannot take it.
    pub fn put_snapshot(
        &self,
        ns: u64,
        id: u64,
        snap: &SeqSnapshot,
    ) -> Result<StoreReceipt, StoreError> {
        let payload = encode_snapshot(snap);
        let ps = self.cfg.page_size as u64;
        let pages = self.pages_for(payload.len()) as u64;
        let mut inner = self.inner.lock().expect("store lock");
        let replaces = inner.snaps.get(&(ns, id)).map(|m| m.extent.pages).unwrap_or(0);
        if !inner.has_room(pages.saturating_sub(replaces), self.cfg.max_pages) {
            inner.stats.rejected_full += 1;
            let free = self.cfg.max_pages.saturating_sub(inner.used_pages() as usize);
            return Err(StoreError::Full { needed_pages: pages as usize, free_pages: free });
        }
        let (extent, rest) = inner.alloc_extent(pages, ps);
        if let Some(r) = rest {
            // The split remainder gets its free marker *before* the record
            // lands, so a crash between the two writes leaves a scannable
            // log either way.
            inner.free_record(r, ps)?;
        }
        let seq =
            inner.write_record(&self.pool, extent, KIND_SNAPSHOT, ns, id, &payload, ps)?;
        let meta = RecordMeta {
            extent,
            payload_len: payload.len(),
            seq,
            tokens: snap.len,
            bytes_by_rung: snap.bytes_by_rung(),
        };
        for (acc, b) in inner.stats.on_disk_bytes_by_rung.iter_mut().zip(meta.bytes_by_rung) {
            *acc += b;
        }
        if let Some(old) = inner.snaps.insert((ns, id), meta) {
            for (acc, b) in inner.stats.on_disk_bytes_by_rung.iter_mut().zip(old.bytes_by_rung) {
                *acc -= b;
            }
            inner.free_record(old.extent, ps)?;
        }
        Ok(StoreReceipt::for_snapshot(snap, pages as usize, payload.len()))
    }

    /// Read a snapshot back, re-validating every checksum on the way —
    /// corrupt pages fail closed with [`StoreError::Corrupt`], never a
    /// garbage snapshot.
    pub fn get_snapshot(
        &self,
        ns: u64,
        id: u64,
    ) -> Result<Option<(SeqSnapshot, StoreReceipt)>, StoreError> {
        let ps = self.cfg.page_size as u64;
        let mut inner = self.inner.lock().expect("store lock");
        let Some(meta) = inner.snaps.get(&(ns, id)).copied() else { return Ok(None) };
        let payload = inner.read_payload(&self.pool, &meta, KIND_SNAPSHOT, ns, id, ps)?;
        let snap = decode_snapshot(&payload)?;
        Ok(Some((snap, StoreReceipt::for_snapshot(&snap, meta.extent.pages as usize, payload.len()))))
    }

    pub fn contains_snapshot(&self, ns: u64, id: u64) -> bool {
        self.inner.lock().expect("store lock").snaps.contains_key(&(ns, id))
    }

    /// Token count of a stored snapshot without touching the disk.
    pub fn snapshot_tokens(&self, ns: u64, id: u64) -> Option<usize> {
        self.inner.lock().expect("store lock").snaps.get(&(ns, id)).map(|m| m.tokens)
    }

    /// Drop a snapshot (free its pages, destroy its header). Returns
    /// whether it existed.
    pub fn delete_snapshot(&self, ns: u64, id: u64) -> Result<bool, StoreError> {
        let ps = self.cfg.page_size as u64;
        let mut inner = self.inner.lock().expect("store lock");
        let Some(meta) = inner.snaps.remove(&(ns, id)) else { return Ok(false) };
        for (acc, b) in inner.stats.on_disk_bytes_by_rung.iter_mut().zip(meta.bytes_by_rung) {
            *acc -= b;
        }
        inner.free_record(meta.extent, ps)?;
        inner.stats.deletes += 1;
        Ok(true)
    }

    /// Register a writer layout (root = chain-root key of `(layout,
    /// block_tokens)`), persisting it so readers after a restart still
    /// know which key spaces exist. Idempotent; returns the root key.
    pub fn register_layout(
        &self,
        layout: &KvLayout,
        block_tokens: usize,
    ) -> Result<u64, StoreError> {
        let root = layout_root_key(layout, block_tokens);
        let ps = self.cfg.page_size as u64;
        let mut inner = self.inner.lock().expect("store lock");
        if inner.layouts.contains_key(&root) {
            return Ok(root);
        }
        let mut payload = Vec::new();
        encode_layout_into(&mut payload, layout);
        let pages = self.pages_for(payload.len()) as u64;
        let (extent, rest) = inner.alloc_extent(pages, ps);
        if let Some(r) = rest {
            inner.free_record(r, ps)?;
        }
        inner.write_record(
            &self.pool,
            extent,
            KIND_LAYOUT,
            root,
            block_tokens as u64,
            &payload,
            ps,
        )?;
        inner.layouts.insert(root, (layout.clone(), block_tokens));
        Ok(root)
    }

    /// Every registered `(root, layout, block_tokens)`, root-ordered
    /// (deterministic adoption tie-breaks depend on this).
    pub fn registered_layouts(&self) -> Vec<(u64, KvLayout, usize)> {
        self.inner
            .lock()
            .expect("store lock")
            .layouts
            .iter()
            .map(|(&root, (l, bt))| (root, l.clone(), *bt))
            .collect()
    }

    /// Publish one full prefix block (a `block_tokens`-long snapshot)
    /// under its chain key. Returns `None` without touching the disk when
    /// the key is already present (another replica won the publish) or
    /// when the store is full and evicting every unlucky LRU prefix block
    /// still cannot make room (session snapshots are never evicted for a
    /// prefix publish).
    pub fn publish_prefix_block(
        &self,
        root: u64,
        chain_key: u64,
        snap: &SeqSnapshot,
    ) -> Result<Option<StoreReceipt>, StoreError> {
        let payload = encode_snapshot(snap);
        let ps = self.cfg.page_size as u64;
        let pages = self.pages_for(payload.len()) as u64;
        let mut inner = self.inner.lock().expect("store lock");
        if inner.prefixes.contains_key(&chain_key) {
            return Ok(None);
        }
        while !inner.has_room(pages, self.cfg.max_pages) {
            let victim = inner
                .prefixes
                .iter()
                .min_by_key(|(_, p)| p.last_used)
                .map(|(&k, _)| k);
            let Some(k) = victim else {
                inner.stats.rejected_full += 1;
                return Ok(None);
            };
            let p = inner.prefixes.remove(&k).expect("victim exists");
            for (acc, b) in
                inner.stats.on_disk_bytes_by_rung.iter_mut().zip(p.meta.bytes_by_rung)
            {
                *acc -= b;
            }
            inner.free_record(p.meta.extent, ps)?;
            inner.stats.prefix_evicted += 1;
            inner.stats.deletes += 1;
        }
        let (extent, rest) = inner.alloc_extent(pages, ps);
        if let Some(r) = rest {
            inner.free_record(r, ps)?;
        }
        let seq =
            inner.write_record(&self.pool, extent, KIND_PREFIX, chain_key, root, &payload, ps)?;
        let meta = RecordMeta {
            extent,
            payload_len: payload.len(),
            seq,
            tokens: snap.len,
            bytes_by_rung: snap.bytes_by_rung(),
        };
        for (acc, b) in inner.stats.on_disk_bytes_by_rung.iter_mut().zip(meta.bytes_by_rung) {
            *acc += b;
        }
        inner.clock += 1;
        let last_used = inner.clock;
        inner.prefixes.insert(chain_key, PrefixMeta { meta, root, last_used });
        inner.stats.prefix_publishes += 1;
        Ok(Some(StoreReceipt::for_snapshot(snap, pages as usize, payload.len())))
    }

    pub fn contains_prefix(&self, chain_key: u64) -> bool {
        self.inner.lock().expect("store lock").prefixes.contains_key(&chain_key)
    }

    /// How many leading keys of `keys` are present — the store-side chain
    /// walk (pure peek: no LRU bump, no I/O).
    pub fn prefix_chain_depth(&self, keys: &[u64]) -> usize {
        let inner = self.inner.lock().expect("store lock");
        keys.iter().take_while(|k| inner.prefixes.contains_key(k)).count()
    }

    /// Fetch one prefix block, bumping its LRU stamp. Fail-closed like
    /// [`PageFileStore::get_snapshot`].
    pub fn get_prefix_block(
        &self,
        chain_key: u64,
    ) -> Result<Option<(SeqSnapshot, StoreReceipt)>, StoreError> {
        let ps = self.cfg.page_size as u64;
        let mut inner = self.inner.lock().expect("store lock");
        let Some(pm) = inner.prefixes.get(&chain_key).copied() else { return Ok(None) };
        inner.clock += 1;
        inner.prefixes.get_mut(&chain_key).expect("present above").last_used = inner.clock;
        let payload =
            inner.read_payload(&self.pool, &pm.meta, KIND_PREFIX, chain_key, pm.root, ps)?;
        let snap = decode_snapshot(&payload)?;
        Ok(Some((
            snap,
            StoreReceipt::for_snapshot(&snap, pm.meta.extent.pages as usize, payload.len()),
        )))
    }

    /// Flush file contents to stable storage.
    pub fn sync(&self) -> Result<(), StoreError> {
        self.inner.lock().expect("store lock").file.sync_all()?;
        Ok(())
    }

    /// Counters snapshot (live occupancy filled in at call time).
    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().expect("store lock");
        let mut s = inner.stats;
        s.snapshots = inner.snaps.len();
        s.prefix_blocks = inner.prefixes.len();
        s.layouts = inner.layouts.len();
        s.used_pages = inner.used_pages() as usize;
        s.capacity_pages = self.cfg.max_pages;
        s
    }

    /// Shared I/O-buffer pool counters.
    pub fn pool_stats(&self) -> PagePoolStats {
        self.pool.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::pool::KvPrecision;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("tmkv-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn snap(len: usize, prec: KvPrecision, tag: u8) -> SeqSnapshot {
        let layout = KvLayout::uniform(prec, 2);
        let (kv_heads, head_dim) = (2, 8);
        let tcb = layout.token_code_bytes(kv_heads, head_dim);
        SeqSnapshot {
            len,
            codes: (0..len * tcb).map(|i| (i as u8).wrapping_mul(7).wrapping_add(tag)).collect(),
            scales: (0..len * 2 * 2 * kv_heads).map(|i| i as f32 + tag as f32).collect(),
            kv_heads,
            head_dim,
            layout,
        }
    }

    #[test]
    fn snapshots_roundtrip_and_survive_reopen() {
        let path = tmp("roundtrip.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, 512, 0);
        let s1 = snap(5, KvPrecision::Int8, 1);
        let s2 = snap(3, KvPrecision::F32, 2);
        {
            let store = PageFileStore::open(cfg.clone()).unwrap();
            store.put_snapshot(1, 10, &s1).unwrap();
            store.put_snapshot(1, 11, &s2).unwrap();
            assert_eq!(store.snapshot_tokens(1, 10), Some(5));
            let (got, _) = store.get_snapshot(1, 10).unwrap().unwrap();
            assert_eq!(got, s1);
        }
        // Reopen: both snapshots recovered byte-exactly, fresh namespaces
        // start above the persisted one.
        let store = PageFileStore::open(cfg).unwrap();
        let st = store.stats();
        assert_eq!(st.recovered_snapshots, 2);
        assert_eq!(st.quarantined_pages, 0);
        assert_eq!(store.get_snapshot(1, 10).unwrap().unwrap().0, s1);
        assert_eq!(store.get_snapshot(1, 11).unwrap().unwrap().0, s2);
        assert!(store.alloc_namespace() > 1);
    }

    #[test]
    fn delete_frees_pages_and_never_resurrects() {
        let path = tmp("delete.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, 512, 0);
        {
            let store = PageFileStore::open(cfg.clone()).unwrap();
            store.put_snapshot(1, 1, &snap(4, KvPrecision::Int4, 3)).unwrap();
            store.put_snapshot(1, 2, &snap(4, KvPrecision::Int4, 4)).unwrap();
            assert!(store.delete_snapshot(1, 1).unwrap());
            assert!(!store.delete_snapshot(1, 1).unwrap());
            assert_eq!(store.stats().snapshots, 1);
        }
        let store = PageFileStore::open(cfg).unwrap();
        assert_eq!(store.stats().recovered_snapshots, 1, "deleted record must not resurrect");
        assert!(store.get_snapshot(1, 1).unwrap().is_none());
        assert!(store.get_snapshot(1, 2).unwrap().is_some());
    }

    #[test]
    fn freed_extents_are_reused_first_fit() {
        let path = tmp("reuse.pages");
        let _ = std::fs::remove_file(&path);
        let store = PageFileStore::open(StoreConfig::with_geometry(&path, 512, 0)).unwrap();
        store.put_snapshot(1, 1, &snap(8, KvPrecision::F32, 1)).unwrap();
        let used_after_first = store.stats().used_pages;
        store.put_snapshot(1, 2, &snap(2, KvPrecision::Int4, 2)).unwrap();
        store.delete_snapshot(1, 1).unwrap();
        // A same-or-smaller record lands inside the freed extent: the file
        // does not grow.
        let len_before = std::fs::metadata(&path).unwrap().len();
        store.put_snapshot(1, 3, &snap(2, KvPrecision::Int4, 5)).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), len_before);
        assert!(store.stats().used_pages < used_after_first + 2 * store.stats().snapshots);
        assert_eq!(store.get_snapshot(1, 3).unwrap().unwrap().0, snap(2, KvPrecision::Int4, 5));
    }

    #[test]
    fn capacity_rejects_snapshots_and_evicts_prefix_lru() {
        let path = tmp("capacity.pages");
        let _ = std::fs::remove_file(&path);
        // Every record here fits in one 512-byte page; capacity 2 pages.
        let store = PageFileStore::open(StoreConfig::with_geometry(&path, 512, 2)).unwrap();
        let b = snap(1, KvPrecision::Int4, 1);
        store.put_snapshot(1, 1, &b).unwrap();
        store.put_snapshot(1, 2, &b).unwrap();
        let err = store.put_snapshot(1, 3, &b).unwrap_err();
        assert!(matches!(err, StoreError::Full { .. }), "{err}");
        // Prefix publishes cannot evict session snapshots.
        assert!(store.publish_prefix_block(7, 100, &b).unwrap().is_none());
        assert_eq!(store.stats().rejected_full, 2);
        // With room, publishes land and LRU eviction cycles them.
        store.delete_snapshot(1, 1).unwrap();
        assert!(store.publish_prefix_block(7, 100, &b).unwrap().is_some());
        assert!(store.publish_prefix_block(7, 101, &b).unwrap().is_none(), "full again");
        assert_eq!(store.stats().prefix_blocks, 1, "victim was the only other prefix block");
    }

    #[test]
    fn prefix_blocks_walk_and_reopen() {
        let path = tmp("prefix.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, 512, 0);
        let layout = KvLayout::uniform(KvPrecision::Int8, 2);
        let b = snap(4, KvPrecision::Int8, 9);
        let root = {
            let store = PageFileStore::open(cfg.clone()).unwrap();
            let root = store.register_layout(&layout, 4).unwrap();
            assert_eq!(store.register_layout(&layout, 4).unwrap(), root, "idempotent");
            assert!(store.publish_prefix_block(root, 1001, &b).unwrap().is_some());
            assert!(store.publish_prefix_block(root, 1002, &b).unwrap().is_some());
            assert!(store.publish_prefix_block(root, 1001, &b).unwrap().is_none(), "dup");
            assert_eq!(store.prefix_chain_depth(&[1001, 1002, 1003]), 2);
            assert_eq!(store.prefix_chain_depth(&[1003, 1001]), 0);
            root
        };
        let store = PageFileStore::open(cfg).unwrap();
        assert_eq!(store.stats().recovered_prefix_blocks, 2);
        let layouts = store.registered_layouts();
        assert_eq!(layouts, vec![(root, layout, 4)], "registry survives restart");
        assert_eq!(store.get_prefix_block(1002).unwrap().unwrap().0, b);
    }

    #[test]
    fn bit_flip_fails_closed_on_read_and_on_reopen() {
        let path = tmp("bitflip.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, 512, 0);
        let store = PageFileStore::open(cfg.clone()).unwrap();
        store.put_snapshot(1, 1, &snap(4, KvPrecision::Int8, 6)).unwrap();
        store.sync().unwrap();
        // Flip one payload bit on disk (page 1, past the record header).
        let mut bytes = std::fs::read(&path).unwrap();
        let at = 512 + HEADER_BYTES + 10;
        bytes[at] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        // The open handle fails closed on read...
        let err = store.get_snapshot(1, 1).unwrap_err();
        assert!(err.is_corrupt(), "{err}");
        drop(store);
        // ...and a reopen quarantines the record instead of serving it.
        let store = PageFileStore::open(cfg).unwrap();
        let st = store.stats();
        assert_eq!(st.recovered_snapshots, 0);
        assert!(st.quarantined_pages > 0);
        assert!(store.get_snapshot(1, 1).unwrap().is_none());
    }

    #[test]
    fn truncation_at_page_boundary_quarantines_the_cut_record() {
        let path = tmp("truncate.pages");
        let _ = std::fs::remove_file(&path);
        let cfg = StoreConfig::with_geometry(&path, 512, 0);
        let big = snap(16, KvPrecision::F32, 2); // multi-page record
        let small = snap(1, KvPrecision::Int4, 1);
        {
            let store = PageFileStore::open(cfg.clone()).unwrap();
            store.put_snapshot(1, 1, &small).unwrap();
            store.put_snapshot(1, 2, &big).unwrap();
            store.sync().unwrap();
        }
        // Cut the file one page into the second (multi-page) record.
        let len = std::fs::metadata(&path).unwrap().len();
        let file = OpenOptions::new().write(true).open(&path).unwrap();
        file.set_len(len - 512).unwrap();
        drop(file);
        let store = PageFileStore::open(cfg).unwrap();
        let st = store.stats();
        assert_eq!(st.recovered_snapshots, 1, "committed record survives");
        assert!(st.quarantined_pages > 0, "cut record is quarantined");
        assert_eq!(store.get_snapshot(1, 1).unwrap().unwrap().0, small);
        assert!(store.get_snapshot(1, 2).unwrap().is_none());
    }

    #[test]
    fn page_size_mismatch_is_a_structured_geometry_error() {
        let path = tmp("geometry.pages");
        let _ = std::fs::remove_file(&path);
        PageFileStore::open(StoreConfig::with_geometry(&path, 512, 0)).unwrap();
        let err = PageFileStore::open(StoreConfig::with_geometry(&path, 1024, 0)).unwrap_err();
        assert!(matches!(err, StoreError::Geometry(_)), "{err}");
        assert!(StoreConfig::with_geometry("/x", 300, 0).validate().is_err(), "non-power-of-two");
    }
}

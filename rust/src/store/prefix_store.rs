//! Shared-prefix resolution against the host-global store.
//!
//! The per-replica [`PrefixCache`](crate::kvcache::PrefixCache) walks
//! chain-hash keys over *its own pool's* blocks; this module walks the
//! same key space over the [`PageFileStore`]'s persisted blocks, across
//! every layout any replica has registered. A replica adopting a hit
//! fetches the block chain, transcodes to its pool's layout when the
//! published layout is wider (the one-way ladder), and imports through the
//! byte-exact `import_seq` path — so a kv16 block published before a pool
//! laddered down to kv4 re-inflates bit-identically to prefilling at kv4
//! directly (the PR 5 warm-restore follow-up).

use super::pagefile::{PageFileStore, StoreReceipt};
use super::StoreError;
use crate::kvcache::prefix::chain_keys_under;
use crate::kvcache::{KvLayout, SeqSnapshot};

/// A resolved store-side prefix match: the deepest persisted block chain
/// covering the head of a prompt, under some registered layout the caller
/// can adopt.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SharedPrefixHit {
    /// Root key of the winning `(layout, block_tokens)` registry entry.
    pub root: u64,
    /// Layout the persisted blocks were published under.
    pub layout: KvLayout,
    /// Block geometry of the chain.
    pub block_tokens: usize,
    /// Chain keys of the matched blocks, shallowest first.
    pub keys: Vec<u64>,
    /// Tokens covered (`keys.len() × block_tokens`).
    pub tokens: usize,
}

/// Find the deepest persisted prefix chain for `prompt` that a pool with
/// `pool_layout`/`block_tokens` can adopt: the published layout must
/// either equal the pool's or transcode down to it (the one-way ladder).
/// At most `max_tokens` tokens are matched (callers cap at prompt_len − 1
/// so at least one token remains to prefill). Ties prefer the pool's exact
/// layout (no transcode work), then the lowest root key — the registry
/// iterates root-ordered, so resolution is deterministic across replicas
/// and restarts.
pub fn resolve_shared_prefix(
    store: &PageFileStore,
    prompt: &[i32],
    pool_layout: &KvLayout,
    block_tokens: usize,
    max_tokens: usize,
) -> Option<SharedPrefixHit> {
    let max_blocks = max_tokens / block_tokens.max(1);
    if max_blocks == 0 {
        return None;
    }
    let mut best: Option<SharedPrefixHit> = None;
    for (root, layout, bt) in store.registered_layouts() {
        if bt != block_tokens {
            continue;
        }
        if layout != *pool_layout && !layout.can_transcode_to(pool_layout) {
            continue;
        }
        let keys = chain_keys_under(root, prompt, block_tokens, max_blocks);
        let depth = store.prefix_chain_depth(&keys);
        if depth == 0 {
            continue;
        }
        let exact = layout == *pool_layout;
        let better = match &best {
            None => true,
            Some(b) => {
                let b_exact = b.layout == *pool_layout;
                depth > b.keys.len() || (depth == b.keys.len() && exact && !b_exact)
            }
        };
        if better {
            best = Some(SharedPrefixHit {
                root,
                layout,
                block_tokens,
                keys: keys[..depth].to_vec(),
                tokens: depth * block_tokens,
            });
        }
    }
    best
}

/// Fetch a resolved chain's blocks and concatenate them into one snapshot
/// (still in the hit's published layout — the caller transcodes if its
/// pool is narrower). Every block is re-validated: checksums on the read
/// path, then geometry/layout/length against the chain's registry entry.
/// A block evicted between resolve and fetch yields `Ok(None)` (the
/// caller falls back to cold prefill); corruption propagates fail-closed.
pub fn fetch_chain(
    store: &PageFileStore,
    hit: &SharedPrefixHit,
) -> Result<Option<(SeqSnapshot, StoreReceipt)>, StoreError> {
    let mut merged: Option<SeqSnapshot> = None;
    let mut receipt = StoreReceipt::default();
    for &key in &hit.keys {
        let Some((block, r)) = store.get_prefix_block(key)? else {
            return Ok(None);
        };
        if block.len != hit.block_tokens || block.layout != hit.layout {
            return Err(StoreError::corrupt(
                "prefix",
                0,
                format!(
                    "chain block holds {} tokens of layout {}, registry says {} of {}",
                    block.len, block.layout, hit.block_tokens, hit.layout
                ),
            ));
        }
        receipt.merge(&r);
        match &mut merged {
            None => merged = Some(block),
            Some(acc) => {
                if block.kv_heads != acc.kv_heads || block.head_dim != acc.head_dim {
                    return Err(StoreError::corrupt(
                        "prefix",
                        0,
                        "chain blocks disagree on kv geometry",
                    ));
                }
                acc.len += block.len;
                acc.codes.extend_from_slice(&block.codes);
                acc.scales.extend_from_slice(&block.scales);
            }
        }
    }
    Ok(merged.map(|s| (s, receipt)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::pool::KvPrecision;
    use crate::store::StoreConfig;

    const BT: usize = 4;

    fn block(layout: &KvLayout, tag: u8) -> SeqSnapshot {
        let (kv_heads, head_dim) = (2, 8);
        let tcb = layout.token_code_bytes(kv_heads, head_dim);
        SeqSnapshot {
            len: BT,
            codes: (0..BT * tcb).map(|i| (i as u8).wrapping_add(tag)).collect(),
            scales: (0..BT * layout.n_layers() * 2 * kv_heads).map(|i| 1.0 + i as f32).collect(),
            kv_heads,
            head_dim,
            layout: layout.clone(),
        }
    }

    fn open(name: &str) -> std::sync::Arc<PageFileStore> {
        let dir = std::env::temp_dir().join(format!("tmkv-prefix-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        PageFileStore::open(StoreConfig::with_geometry(path, 512, 0)).unwrap()
    }

    #[test]
    fn resolves_deepest_chain_and_fetches_concatenated() {
        let store = open("resolve.pages");
        let layout = KvLayout::uniform(KvPrecision::Int8, 2);
        let root = store.register_layout(&layout, BT).unwrap();
        let prompt: Vec<i32> = (0..12).collect();
        let keys = chain_keys_under(root, &prompt, BT, 8);
        let (b0, b1) = (block(&layout, 1), block(&layout, 2));
        store.publish_prefix_block(root, keys[0], &b0).unwrap();
        store.publish_prefix_block(root, keys[1], &b1).unwrap();

        let hit = resolve_shared_prefix(&store, &prompt, &layout, BT, prompt.len()).unwrap();
        assert_eq!((hit.tokens, hit.keys.len(), hit.root), (8, 2, root));
        let (merged, receipt) = fetch_chain(&store, &hit).unwrap().unwrap();
        assert_eq!(merged.len, 8);
        assert_eq!(&merged.codes[..b0.codes.len()], &b0.codes[..]);
        assert_eq!(&merged.codes[b0.codes.len()..], &b1.codes[..]);
        assert_eq!(receipt.snapshot_bytes(), b0.bytes_by_rung().iter().sum::<usize>() * 2);

        // max_tokens caps the matched depth (leave one token to prefill).
        let hit = resolve_shared_prefix(&store, &prompt, &layout, BT, 7).unwrap();
        assert_eq!(hit.tokens, 4);
        // A different prompt head misses entirely.
        let other: Vec<i32> = (100..112).collect();
        assert!(resolve_shared_prefix(&store, &other, &layout, BT, 12).is_none());
    }

    #[test]
    fn cross_layout_adoption_prefers_exact_and_respects_the_ladder() {
        let store = open("ladder.pages");
        let kv16 = KvLayout::uniform(KvPrecision::F32, 2);
        let kv4 = KvLayout::uniform(KvPrecision::Int4, 2);
        let r16 = store.register_layout(&kv16, BT).unwrap();
        let r4 = store.register_layout(&kv4, BT).unwrap();
        let prompt: Vec<i32> = (0..8).collect();
        let k16 = chain_keys_under(r16, &prompt, BT, 8);
        let k4 = chain_keys_under(r4, &prompt, BT, 8);
        store.publish_prefix_block(r16, k16[0], &block(&kv16, 3)).unwrap();
        store.publish_prefix_block(r4, k4[0], &block(&kv4, 4)).unwrap();

        // A kv4 pool can adopt either chain; equal depth prefers its own.
        let hit = resolve_shared_prefix(&store, &prompt, &kv4, BT, 8).unwrap();
        assert_eq!(hit.layout, kv4);
        // With only the kv16 chain published deeper, the wider chain wins
        // and the caller transcodes down.
        store.publish_prefix_block(r16, k16[1], &block(&kv16, 5)).unwrap();
        let hit = resolve_shared_prefix(&store, &prompt, &kv4, BT, 8).unwrap();
        assert_eq!((hit.layout.clone(), hit.tokens), (kv16.clone(), 8));
        let (merged, _) = fetch_chain(&store, &hit).unwrap().unwrap();
        assert!(merged.transcode_to(&kv4).is_ok());
        // A kv16 pool cannot adopt kv4 blocks (no upward transcode): only
        // the kv16 chain resolves for it.
        let hit = resolve_shared_prefix(&store, &prompt, &kv16, BT, 8).unwrap();
        assert_eq!(hit.layout, kv16);
    }

    #[test]
    fn evicted_block_mid_fetch_falls_back_to_none() {
        let store = open("evict.pages");
        let layout = KvLayout::uniform(KvPrecision::Int8, 2);
        let root = store.register_layout(&layout, BT).unwrap();
        let prompt: Vec<i32> = (0..4).collect();
        let keys = chain_keys_under(root, &prompt, BT, 8);
        store.publish_prefix_block(root, keys[0], &block(&layout, 6)).unwrap();
        let hit = resolve_shared_prefix(&store, &prompt, &layout, BT, 4).unwrap();
        // Simulate an eviction racing the fetch by resolving a hit whose
        // key no longer exists.
        let stale = SharedPrefixHit { keys: vec![keys[0] ^ 1], ..hit };
        assert!(fetch_chain(&store, &stale).unwrap().is_none());
    }
}

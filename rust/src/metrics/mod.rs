//! Serving metrics: latency percentiles, TTFT, and throughput — the three
//! evaluation metrics of §5.1 — plus the prefix-cache effectiveness summary
//! (hit rate, blocks saved, prefill tokens skipped) and the preemption
//! summary (victims, swap traffic, recompute volume, OOM aborts).

use crate::coordinator::PreemptStats;
use crate::kvcache::{PrefixCacheStats, SwapStats};

/// Prefix-cache effectiveness, derived from the engine's
/// [`PrefixCacheStats`] counters. This is what the server's stats line and
/// the bench tables report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheSummary {
    /// Admission lookups.
    pub lookups: usize,
    /// Lookups matching at least one block.
    pub hits: usize,
    /// Pool blocks reused instead of re-prefilled.
    pub blocks_saved: usize,
    /// Prompt tokens whose prefill was skipped entirely.
    pub prefill_tokens_skipped: usize,
    /// Cached blocks reclaimed under memory pressure.
    pub evicted_blocks: usize,
}

impl PrefixCacheSummary {
    /// Fraction of admissions that reused at least one resident block.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

impl From<PrefixCacheStats> for PrefixCacheSummary {
    fn from(s: PrefixCacheStats) -> Self {
        Self {
            lookups: s.lookups,
            hits: s.hits,
            blocks_saved: s.blocks_shared,
            prefill_tokens_skipped: s.hit_tokens,
            evicted_blocks: s.evicted_blocks,
        }
    }
}

/// Preemption effectiveness under KV pressure (DESIGN.md §8): how often
/// the engine preempted instead of aborting, how it preserved the victims,
/// and what the preservation cost. This is what the server's stats line
/// and the `bench preempt` table report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PreemptionSummary {
    /// Victims preempted (swap + recompute).
    pub preemptions: usize,
    /// Victims preserved by swapping KV to the host store.
    pub swap_preemptions: usize,
    /// Victims released for re-prefill on resume.
    pub recompute_preemptions: usize,
    /// Tokens queued for re-prefill by recompute preemptions.
    pub recomputed_tokens: usize,
    /// Pool blocks shipped to the host (cumulative).
    pub swapped_out_blocks: usize,
    /// Pool blocks restored from the host (cumulative).
    pub swapped_in_blocks: usize,
    /// High-water mark of host blocks resident at once.
    pub swap_peak_blocks: usize,
    /// Sequences lost to pool exhaustion (abort mode, or a sole runner no
    /// preemption could save).
    pub oom_aborts: usize,
}

impl PreemptionSummary {
    pub fn new(p: PreemptStats, s: SwapStats) -> Self {
        Self {
            preemptions: p.preemptions,
            swap_preemptions: p.swap_preemptions,
            recompute_preemptions: p.recompute_preemptions,
            recomputed_tokens: p.recomputed_tokens,
            swapped_out_blocks: s.swapped_out_blocks,
            swapped_in_blocks: s.swapped_in_blocks,
            swap_peak_blocks: s.peak_blocks,
            oom_aborts: p.oom_aborts,
        }
    }

    /// Fraction of preemptions preserved by swap (0 when none happened).
    pub fn swap_fraction(&self) -> f64 {
        if self.preemptions == 0 {
            0.0
        } else {
            self.swap_preemptions as f64 / self.preemptions as f64
        }
    }
}

/// Accumulates per-request measurements and computes the paper's metrics.
#[derive(Debug, Default, Clone)]
pub struct MetricsCollector {
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    /// (completion time, generated tokens) pairs for throughput windows.
    completions: Vec<(f64, usize)>,
    prompt_tokens: usize,
    gen_tokens: usize,
}

/// A percentile summary of one latency series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished request.
    pub fn record(&mut self, latency_s: f64, ttft_s: f64, done_at_s: f64,
                  prompt_tokens: usize, gen_tokens: usize) {
        self.latencies.push(latency_s);
        if ttft_s.is_finite() {
            self.ttfts.push(ttft_s);
        }
        self.completions.push((done_at_s, gen_tokens));
        self.prompt_tokens += prompt_tokens;
        self.gen_tokens += gen_tokens;
    }

    pub fn count(&self) -> usize {
        self.latencies.len()
    }

    pub fn latency_percentiles(&self) -> Option<Percentiles> {
        percentiles(&self.latencies)
    }

    pub fn ttft_percentiles(&self) -> Option<Percentiles> {
        percentiles(&self.ttfts)
    }

    /// Requests per second over the observed completion window.
    pub fn request_throughput(&self) -> f64 {
        let end = self.completions.iter().map(|c| c.0).fold(0.0, f64::max);
        if end <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / end
    }

    /// Generated tokens per second over the observed window.
    pub fn token_throughput(&self) -> f64 {
        let end = self.completions.iter().map(|c| c.0).fold(0.0, f64::max);
        if end <= 0.0 {
            return 0.0;
        }
        self.gen_tokens as f64 / end
    }

    pub fn total_tokens(&self) -> (usize, usize) {
        (self.prompt_tokens, self.gen_tokens)
    }
}

/// Nearest-rank percentiles (the convention serving papers use).
pub fn percentiles(xs: &[f64]) -> Option<Percentiles> {
    if xs.is_empty() {
        return None;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pick = |p: f64| {
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    };
    Some(Percentiles {
        p50: pick(50.0),
        p90: pick(90.0),
        p95: pick(95.0),
        p99: pick(99.0),
        mean: v.iter().sum::<f64>() / v.len() as f64,
        max: *v.last().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = percentiles(&xs).unwrap();
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p90, 90.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentiles_single_sample() {
        let p = percentiles(&[3.0]).unwrap();
        assert_eq!(p.p50, 3.0);
        assert_eq!(p.p99, 3.0);
    }

    #[test]
    fn empty_is_none() {
        assert!(percentiles(&[]).is_none());
        assert!(MetricsCollector::new().latency_percentiles().is_none());
    }

    #[test]
    fn throughput_over_window() {
        let mut m = MetricsCollector::new();
        m.record(1.0, 0.1, 5.0, 100, 50);
        m.record(2.0, 0.2, 10.0, 100, 150);
        assert!((m.request_throughput() - 0.2).abs() < 1e-9);
        assert!((m.token_throughput() - 20.0).abs() < 1e-9);
        assert_eq!(m.total_tokens(), (200, 200));
    }

    #[test]
    fn nan_ttft_skipped() {
        let mut m = MetricsCollector::new();
        m.record(1.0, f64::NAN, 1.0, 10, 10);
        m.record(1.0, 0.5, 2.0, 10, 10);
        let p = m.ttft_percentiles().unwrap();
        assert_eq!(p.p50, 0.5);
    }

    #[test]
    fn unsorted_input_handled() {
        let p = percentiles(&[5.0, 1.0, 3.0, 2.0, 4.0]).unwrap();
        assert_eq!(p.p50, 3.0);
        assert_eq!(p.max, 5.0);
    }

    #[test]
    fn preemption_summary_merges_decision_and_transfer_counters() {
        let s = PreemptionSummary::new(
            PreemptStats {
                preemptions: 5,
                swap_preemptions: 3,
                recompute_preemptions: 2,
                recomputed_tokens: 80,
                oom_aborts: 1,
            },
            SwapStats {
                swap_outs: 3,
                swap_ins: 3,
                swapped_out_blocks: 12,
                swapped_in_blocks: 12,
                dropped: 0,
                peak_blocks: 8,
            },
        );
        assert_eq!(s.preemptions, 5);
        assert_eq!(s.swapped_out_blocks, 12);
        assert_eq!(s.swap_peak_blocks, 8);
        assert!((s.swap_fraction() - 0.6).abs() < 1e-12);
        assert_eq!(PreemptionSummary::default().swap_fraction(), 0.0, "no NaN on idle engines");
    }

    #[test]
    fn prefix_cache_summary_hit_rate() {
        assert_eq!(PrefixCacheSummary::default().hit_rate(), 0.0, "no lookups → 0, not NaN");
        let s = PrefixCacheSummary::from(PrefixCacheStats {
            lookups: 4,
            hits: 3,
            hit_tokens: 96,
            blocks_shared: 6,
            inserted_blocks: 8,
            evicted_blocks: 2,
        });
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.blocks_saved, 6);
        assert_eq!(s.prefill_tokens_skipped, 96);
        assert_eq!(s.evicted_blocks, 2);
    }
}

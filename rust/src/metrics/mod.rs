//! Serving metrics: latency, TTFT, and TPOT percentiles plus throughput —
//! the evaluation metrics of §5.1 — plus the prefix-cache effectiveness
//! summary (hit rate, blocks saved, prefill tokens skipped) and the
//! preemption summary (victims, swap traffic, recompute volume, OOM
//! aborts). TPOT (time per output token) is the steady-state decode pace:
//! `(latency − ttft) / (generated − 1)`, defined only for requests that
//! emitted at least two tokens.

use crate::coordinator::PreemptStats;
use crate::kvcache::{PrefixCacheStats, SwapStats};
use crate::util::json::Json;

/// Prefix-cache effectiveness, derived from the engine's
/// [`PrefixCacheStats`] counters. This is what the server's stats line and
/// the bench tables report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheSummary {
    /// Admission lookups.
    pub lookups: usize,
    /// Lookups matching at least one block.
    pub hits: usize,
    /// Pool blocks reused instead of re-prefilled.
    pub blocks_saved: usize,
    /// Prompt tokens whose prefill was skipped entirely.
    pub prefill_tokens_skipped: usize,
    /// Cached blocks reclaimed under memory pressure.
    pub evicted_blocks: usize,
    /// Cached blocks dropped wholesale by precision-ladder relayouts (a
    /// laddered pool must never serve stale-precision prefixes).
    pub invalidated_blocks: usize,
}

impl PrefixCacheSummary {
    /// Fraction of admissions that reused at least one resident block.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }
}

impl From<PrefixCacheStats> for PrefixCacheSummary {
    fn from(s: PrefixCacheStats) -> Self {
        Self {
            lookups: s.lookups,
            hits: s.hits,
            blocks_saved: s.blocks_shared,
            prefill_tokens_skipped: s.hit_tokens,
            evicted_blocks: s.evicted_blocks,
            invalidated_blocks: s.invalidated_blocks,
        }
    }
}

/// Preemption effectiveness under KV pressure (DESIGN.md §8): how often
/// the engine preempted instead of aborting, how it preserved the victims,
/// and what the preservation cost. This is what the server's stats line
/// and the `bench preempt` table report.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PreemptionSummary {
    /// Victims preempted (swap + recompute).
    pub preemptions: usize,
    /// Victims preserved by swapping KV to the host store.
    pub swap_preemptions: usize,
    /// Victims released for re-prefill on resume.
    pub recompute_preemptions: usize,
    /// Tokens queued for re-prefill by recompute preemptions.
    pub recomputed_tokens: usize,
    /// Victims preserved by a pool-wide precision-ladder rung.
    pub ladder_preemptions: usize,
    /// Pool-wide ladder rungs taken.
    pub ladder_events: usize,
    /// Modeled HBM traffic of all ladder transcodes, bytes.
    pub ladder_transcoded_bytes: usize,
    /// Pool capacity gained by laddering, bytes.
    pub ladder_freed_bytes: usize,
    /// Generated tokens dropped (and regenerated) by ladder restarts.
    pub ladder_dropped_tokens: usize,
    /// Pool blocks shipped to the host (cumulative).
    pub swapped_out_blocks: usize,
    /// Pool blocks restored from the host (cumulative).
    pub swapped_in_blocks: usize,
    /// High-water mark of host blocks resident at once.
    pub swap_peak_blocks: usize,
    /// Sequences lost to pool exhaustion (abort mode, or a sole runner no
    /// preemption could save).
    pub oom_aborts: usize,
}

impl PreemptionSummary {
    pub fn new(p: PreemptStats, s: SwapStats) -> Self {
        Self {
            preemptions: p.preemptions,
            swap_preemptions: p.swap_preemptions,
            recompute_preemptions: p.recompute_preemptions,
            recomputed_tokens: p.recomputed_tokens,
            ladder_preemptions: p.ladder_preemptions,
            ladder_events: p.ladder_events,
            ladder_transcoded_bytes: p.ladder_transcoded_bytes,
            ladder_freed_bytes: p.ladder_freed_bytes,
            ladder_dropped_tokens: p.ladder_dropped_tokens,
            swapped_out_blocks: s.swapped_out_blocks,
            swapped_in_blocks: s.swapped_in_blocks,
            swap_peak_blocks: s.peak_blocks,
            oom_aborts: p.oom_aborts,
        }
    }

    /// Fraction of preemptions preserved by swap (0 when none happened).
    pub fn swap_fraction(&self) -> f64 {
        if self.preemptions == 0 {
            0.0
        } else {
            self.swap_preemptions as f64 / self.preemptions as f64
        }
    }
}

/// Precision-attributed byte telemetry (DESIGN.md §12): where the modeled
/// HBM/PCIe traffic went, split per `KvPrecision` ladder rung (index =
/// `ladder_rank()`: 0 = kv16, 1 = kv8, 2 = kv4 — [`crate::trace::RUNG_NAMES`]).
/// Every bucket reconciles exactly (`==`) with the corresponding trace
/// events; the totals reconcile with `EngineStats`/`PreemptStats`.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TelemetrySummary {
    /// Decode/prefill KV-gather HBM read bytes per rung (sums to
    /// `EngineStats::gather_hbm_bytes`).
    pub gather_hbm_bytes_by_rung: [usize; 3],
    /// Ladder transcode read+write HBM bytes per destination rung (sums to
    /// `PreemptStats::ladder_transcoded_bytes`).
    pub transcode_bytes_by_rung: [usize; 3],
    /// Swap-preemption PCIe bytes (out + in, codes + scales) per rung.
    pub swap_pcie_bytes_by_rung: [usize; 3],
    /// Cross-replica KV-migration PCIe bytes (snapshot export + import,
    /// codes + scales) per rung, attributed from each snapshot's recorded
    /// extents. Kept separate from swap traffic so the swap ↔ preemption
    /// reconciliation stays exact under disaggregated serving.
    pub migrate_pcie_bytes_by_rung: [usize; 3],
    /// Page-file store disk-tier bytes (disk-tier swap round trips plus
    /// shared-prefix publications and adoptions) per rung, attributed from
    /// each snapshot's recorded extents. Disjoint from the PCIe buckets —
    /// a disk-tier swap shows the same bytes once here and once in
    /// `swap_pcie_bytes_by_rung`, one per bus the bytes crossed.
    pub store_disk_bytes_by_rung: [usize; 3],
    /// Per-layer resident-precision occupancy: how many of the pool's
    /// layers currently sit at each rung (a `KvLayout::rung_histogram`
    /// snapshot, not a counter — `merge` sums it across replicas into a
    /// fleet-wide layer histogram).
    pub occupancy_layers_by_rung: [usize; 3],
}

impl TelemetrySummary {
    /// Element-wise sum — fleet aggregation. Commutative and associative,
    /// so merge order can never change a total.
    pub fn merge(&mut self, other: &TelemetrySummary) {
        for i in 0..3 {
            self.gather_hbm_bytes_by_rung[i] += other.gather_hbm_bytes_by_rung[i];
            self.transcode_bytes_by_rung[i] += other.transcode_bytes_by_rung[i];
            self.swap_pcie_bytes_by_rung[i] += other.swap_pcie_bytes_by_rung[i];
            self.migrate_pcie_bytes_by_rung[i] += other.migrate_pcie_bytes_by_rung[i];
            self.store_disk_bytes_by_rung[i] += other.store_disk_bytes_by_rung[i];
            self.occupancy_layers_by_rung[i] += other.occupancy_layers_by_rung[i];
        }
    }

    /// All-rung gather total (== `EngineStats::gather_hbm_bytes`).
    pub fn gather_hbm_bytes(&self) -> usize {
        self.gather_hbm_bytes_by_rung.iter().sum()
    }

    /// All-rung transcode total (== `PreemptStats::ladder_transcoded_bytes`).
    pub fn transcode_bytes(&self) -> usize {
        self.transcode_bytes_by_rung.iter().sum()
    }

    /// All-rung swap PCIe total.
    pub fn swap_pcie_bytes(&self) -> usize {
        self.swap_pcie_bytes_by_rung.iter().sum()
    }

    /// All-rung migration PCIe total.
    pub fn migrate_pcie_bytes(&self) -> usize {
        self.migrate_pcie_bytes_by_rung.iter().sum()
    }

    /// All-rung page-file disk-tier total.
    pub fn store_disk_bytes(&self) -> usize {
        self.store_disk_bytes_by_rung.iter().sum()
    }

    /// The stats-probe object: three per-rung byte arrays, the occupancy
    /// histogram, and the rung-name legend.
    pub fn to_json(&self) -> Json {
        let rungs = |a: [usize; 3]| {
            crate::util::json::arr(a.iter().map(|&b| Json::from(b)))
        };
        crate::util::json::obj([
            ("rungs", crate::util::json::arr(crate::trace::RUNG_NAMES.iter().map(|&n| Json::from(n)))),
            ("gather_hbm_bytes_by_rung", rungs(self.gather_hbm_bytes_by_rung)),
            ("transcode_bytes_by_rung", rungs(self.transcode_bytes_by_rung)),
            ("swap_pcie_bytes_by_rung", rungs(self.swap_pcie_bytes_by_rung)),
            ("migrate_pcie_bytes_by_rung", rungs(self.migrate_pcie_bytes_by_rung)),
            ("store_disk_bytes_by_rung", rungs(self.store_disk_bytes_by_rung)),
            ("occupancy_layers_by_rung", rungs(self.occupancy_layers_by_rung)),
        ])
    }
}

/// Accumulates per-request measurements and computes the paper's metrics.
#[derive(Debug, Default, Clone)]
pub struct MetricsCollector {
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    /// Per-request time-per-output-token (requests with ≥ 2 tokens only).
    tpots: Vec<f64>,
    /// (completion time, generated tokens) pairs for throughput windows.
    completions: Vec<(f64, usize)>,
    prompt_tokens: usize,
    gen_tokens: usize,
}

/// A percentile summary of one latency series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Percentiles {
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl MetricsCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished request.
    pub fn record(&mut self, latency_s: f64, ttft_s: f64, done_at_s: f64,
                  prompt_tokens: usize, gen_tokens: usize) {
        self.latencies.push(latency_s);
        if ttft_s.is_finite() {
            self.ttfts.push(ttft_s);
            if gen_tokens > 1 {
                self.tpots.push((latency_s - ttft_s).max(0.0) / (gen_tokens - 1) as f64);
            }
        }
        self.completions.push((done_at_s, gen_tokens));
        self.prompt_tokens += prompt_tokens;
        self.gen_tokens += gen_tokens;
    }

    /// Merge another collector's samples into this one (fleet aggregation:
    /// per-replica series concatenate; each sample is its own duration, so
    /// replicas with independent clocks merge soundly).
    pub fn merge(&mut self, other: &MetricsCollector) {
        self.latencies.extend_from_slice(&other.latencies);
        self.ttfts.extend_from_slice(&other.ttfts);
        self.tpots.extend_from_slice(&other.tpots);
        self.completions.extend_from_slice(&other.completions);
        self.prompt_tokens += other.prompt_tokens;
        self.gen_tokens += other.gen_tokens;
    }

    pub fn count(&self) -> usize {
        self.latencies.len()
    }

    pub fn latency_percentiles(&self) -> Option<Percentiles> {
        percentiles(&self.latencies)
    }

    pub fn ttft_percentiles(&self) -> Option<Percentiles> {
        percentiles(&self.ttfts)
    }

    /// Time-per-output-token percentiles (None until a request with ≥ 2
    /// generated tokens completes).
    pub fn tpot_percentiles(&self) -> Option<Percentiles> {
        percentiles(&self.tpots)
    }

    /// Requests per second over the observed completion window.
    pub fn request_throughput(&self) -> f64 {
        let end = self.completions.iter().map(|c| c.0).fold(0.0, f64::max);
        if end <= 0.0 {
            return 0.0;
        }
        self.count() as f64 / end
    }

    /// Generated tokens per second over the observed window.
    pub fn token_throughput(&self) -> f64 {
        let end = self.completions.iter().map(|c| c.0).fold(0.0, f64::max);
        if end <= 0.0 {
            return 0.0;
        }
        self.gen_tokens as f64 / end
    }

    pub fn total_tokens(&self) -> (usize, usize) {
        (self.prompt_tokens, self.gen_tokens)
    }
}

/// The protocol's three percentile series and their p50/p95/p99 probe
/// field names (DESIGN.md §4) — static strings to satisfy
/// `util::json::obj`'s `&'static str` key contract.
pub const LATENCY_PCTL_KEYS: [&str; 3] = ["latency_p50_s", "latency_p95_s", "latency_p99_s"];
pub const TTFT_PCTL_KEYS: [&str; 3] = ["ttft_p50_s", "ttft_p95_s", "ttft_p99_s"];
pub const TPOT_PCTL_KEYS: [&str; 3] = ["tpot_p50_s", "tpot_p95_s", "tpot_p99_s"];

/// p50/p95/p99 probe fields for one series under the given key triple
/// (0 until the series has samples — JSON carries no NaN).
pub fn percentile_fields(
    keys: [&'static str; 3],
    p: Option<Percentiles>,
) -> Vec<(&'static str, Json)> {
    let (p50, p95, p99) = p.map(|p| (p.p50, p.p95, p.p99)).unwrap_or((0.0, 0.0, 0.0));
    vec![(keys[0], Json::from(p50)), (keys[1], Json::from(p95)), (keys[2], Json::from(p99))]
}

/// Nearest-rank percentiles (the convention serving papers use).
pub fn percentiles(xs: &[f64]) -> Option<Percentiles> {
    if xs.is_empty() {
        return None;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pick = |p: f64| {
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    };
    Some(Percentiles {
        p50: pick(50.0),
        p90: pick(90.0),
        p95: pick(95.0),
        p99: pick(99.0),
        mean: v.iter().sum::<f64>() / v.len() as f64,
        max: *v.last().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let p = percentiles(&xs).unwrap();
        assert_eq!(p.p50, 50.0);
        assert_eq!(p.p90, 90.0);
        assert_eq!(p.p99, 99.0);
        assert_eq!(p.max, 100.0);
        assert!((p.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn percentiles_single_sample() {
        // Nearest-rank at n=1: every percentile is the sample itself (rank
        // ceil(p/100 · 1) clamps to 1).
        let p = percentiles(&[3.0]).unwrap();
        assert_eq!(p.p50, 3.0);
        assert_eq!(p.p90, 3.0);
        assert_eq!(p.p95, 3.0);
        assert_eq!(p.p99, 3.0);
        assert_eq!(p.max, 3.0);
        assert_eq!(p.mean, 3.0);
    }

    #[test]
    fn percentiles_two_samples() {
        // Nearest-rank at n=2: p50 → rank ceil(1.0) = 1 (the smaller
        // sample); p90/p95/p99 → rank ceil(1.8/1.9/1.98) = 2 (the larger).
        let p = percentiles(&[7.0, 1.0]).unwrap();
        assert_eq!(p.p50, 1.0);
        assert_eq!(p.p90, 7.0);
        assert_eq!(p.p95, 7.0);
        assert_eq!(p.p99, 7.0);
        assert_eq!(p.max, 7.0);
        assert_eq!(p.mean, 4.0);
    }

    #[test]
    fn tpot_is_decode_pace() {
        let mut m = MetricsCollector::new();
        // 10 tokens over (2.0 − 0.2)s of decode → 0.2 s/token.
        m.record(2.0, 0.2, 2.0, 100, 10);
        let p = m.tpot_percentiles().unwrap();
        assert!((p.p50 - 0.2).abs() < 1e-12, "{}", p.p50);
        assert_eq!(p.p50, p.p99, "single sample");
    }

    #[test]
    fn tpot_skips_degenerate_requests() {
        let mut m = MetricsCollector::new();
        m.record(1.0, 1.0, 1.0, 10, 1); // one token: no decode interval
        m.record(1.0, f64::NAN, 2.0, 10, 8); // aborted before first token
        assert!(m.tpot_percentiles().is_none());
        m.record(1.1, 0.1, 3.0, 10, 11);
        assert!((m.tpot_percentiles().unwrap().p50 - 0.1).abs() < 1e-12);
    }

    #[test]
    fn percentile_fields_zero_when_empty_and_filled_otherwise() {
        for (k, v) in percentile_fields(TPOT_PCTL_KEYS, None) {
            assert!(k.starts_with("tpot_p"));
            assert_eq!(v.as_f64(), Some(0.0));
        }
        let p = percentiles(&[1.0, 3.0]).unwrap();
        let fields = percentile_fields(LATENCY_PCTL_KEYS, Some(p));
        assert_eq!(fields[0], ("latency_p50_s", Json::from(1.0)));
        assert_eq!(fields[2], ("latency_p99_s", Json::from(3.0)));
    }

    #[test]
    fn merge_concatenates_series() {
        let mut a = MetricsCollector::new();
        a.record(1.0, 0.1, 1.0, 10, 5);
        let mut b = MetricsCollector::new();
        b.record(3.0, 0.3, 2.0, 20, 9);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.total_tokens(), (30, 14));
        assert_eq!(a.latency_percentiles().unwrap().max, 3.0);
        assert_eq!(a.tpot_percentiles().unwrap().max, (3.0 - 0.3) / 8.0);
    }

    #[test]
    fn empty_is_none() {
        assert!(percentiles(&[]).is_none());
        assert!(MetricsCollector::new().latency_percentiles().is_none());
    }

    #[test]
    fn throughput_over_window() {
        let mut m = MetricsCollector::new();
        m.record(1.0, 0.1, 5.0, 100, 50);
        m.record(2.0, 0.2, 10.0, 100, 150);
        assert!((m.request_throughput() - 0.2).abs() < 1e-9);
        assert!((m.token_throughput() - 20.0).abs() < 1e-9);
        assert_eq!(m.total_tokens(), (200, 200));
    }

    #[test]
    fn nan_ttft_skipped() {
        let mut m = MetricsCollector::new();
        m.record(1.0, f64::NAN, 1.0, 10, 10);
        m.record(1.0, 0.5, 2.0, 10, 10);
        let p = m.ttft_percentiles().unwrap();
        assert_eq!(p.p50, 0.5);
    }

    #[test]
    fn unsorted_input_handled() {
        let p = percentiles(&[5.0, 1.0, 3.0, 2.0, 4.0]).unwrap();
        assert_eq!(p.p50, 3.0);
        assert_eq!(p.max, 5.0);
    }

    #[test]
    fn preemption_summary_merges_decision_and_transfer_counters() {
        let s = PreemptionSummary::new(
            PreemptStats {
                preemptions: 5,
                swap_preemptions: 3,
                recompute_preemptions: 1,
                recomputed_tokens: 80,
                ladder_preemptions: 1,
                ladder_events: 1,
                ladder_transcoded_bytes: 4096,
                ladder_freed_bytes: 2048,
                ladder_dropped_tokens: 7,
                oom_aborts: 1,
            },
            SwapStats {
                swap_outs: 3,
                swap_ins: 3,
                swapped_out_blocks: 12,
                swapped_in_blocks: 12,
                dropped: 0,
                peak_blocks: 8,
            },
        );
        assert_eq!(s.preemptions, 5);
        assert_eq!(
            s.swap_preemptions + s.recompute_preemptions + s.ladder_preemptions,
            s.preemptions,
            "per-mechanism buckets partition the preemption count"
        );
        assert_eq!(s.ladder_events, 1);
        assert_eq!(s.ladder_transcoded_bytes, 4096);
        assert_eq!(s.swapped_out_blocks, 12);
        assert_eq!(s.swap_peak_blocks, 8);
        assert!((s.swap_fraction() - 0.6).abs() < 1e-12);
        assert_eq!(PreemptionSummary::default().swap_fraction(), 0.0, "no NaN on idle engines");
    }

    #[test]
    fn metrics_merge_totals_survive_order_permutations() {
        // Fleet aggregation must be order-insensitive in every *total*:
        // merge the same three collectors in all six orders and demand
        // identical counts, token sums, and percentile summaries.
        let mut parts = Vec::new();
        for r in 0..3usize {
            let mut m = MetricsCollector::new();
            for i in 0..(r + 2) {
                let x = (r * 7 + i) as f64;
                m.record(1.0 + x, 0.1 + x / 10.0, 1.0 + x, 10 + i, 5 + r);
            }
            parts.push(m);
        }
        let orders =
            [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
        let mut baseline: Option<(usize, (usize, usize), Percentiles, Percentiles)> = None;
        for ord in orders {
            let mut acc = MetricsCollector::new();
            for &i in &ord {
                acc.merge(&parts[i]);
            }
            let got = (
                acc.count(),
                acc.total_tokens(),
                acc.latency_percentiles().unwrap(),
                acc.tpot_percentiles().unwrap(),
            );
            match &baseline {
                None => baseline = Some(got),
                Some(b) => assert_eq!(*b, got, "order {ord:?} drifted"),
            }
        }
    }

    #[test]
    fn telemetry_merge_is_exact_and_order_insensitive() {
        let mk = |s: usize| TelemetrySummary {
            gather_hbm_bytes_by_rung: [s, 2 * s, 3 * s],
            transcode_bytes_by_rung: [0, s, 0],
            swap_pcie_bytes_by_rung: [s, 0, 7 * s],
            migrate_pcie_bytes_by_rung: [0, 5 * s, s],
            store_disk_bytes_by_rung: [s, s, 0],
            occupancy_layers_by_rung: [1, 2, 1],
        };
        let parts = [mk(3), mk(11), mk(40)];
        let orders =
            [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
        let mut baseline: Option<TelemetrySummary> = None;
        for ord in orders {
            let mut acc = TelemetrySummary::default();
            for &i in &ord {
                acc.merge(&parts[i]);
            }
            match &baseline {
                None => baseline = Some(acc),
                Some(b) => assert_eq!(*b, acc, "order {ord:?} drifted"),
            }
        }
        let total = baseline.unwrap();
        assert_eq!(total.gather_hbm_bytes_by_rung, [54, 108, 162]);
        assert_eq!(total.gather_hbm_bytes(), 324);
        assert_eq!(total.transcode_bytes(), 54);
        assert_eq!(total.swap_pcie_bytes(), 54 + 7 * 54);
        assert_eq!(total.migrate_pcie_bytes(), 5 * 54 + 54);
        assert_eq!(total.store_disk_bytes(), 2 * 54);
        assert_eq!(total.occupancy_layers_by_rung, [3, 6, 3]);
        // The probe object round-trips with the rung legend attached.
        let j = Json::parse(&total.to_json().dump()).unwrap();
        assert_eq!(j.req_arr("rungs").unwrap().len(), 3);
        assert_eq!(
            j.req_arr("gather_hbm_bytes_by_rung").unwrap()[1].as_usize(),
            Some(108)
        );
    }

    #[test]
    fn prefix_cache_summary_hit_rate() {
        assert_eq!(PrefixCacheSummary::default().hit_rate(), 0.0, "no lookups → 0, not NaN");
        let s = PrefixCacheSummary::from(PrefixCacheStats {
            lookups: 4,
            hits: 3,
            hit_tokens: 96,
            blocks_shared: 6,
            inserted_blocks: 8,
            evicted_blocks: 2,
            invalidated_blocks: 5,
        });
        assert!((s.hit_rate() - 0.75).abs() < 1e-12);
        assert_eq!(s.blocks_saved, 6);
        assert_eq!(s.prefill_tokens_skipped, 96);
        assert_eq!(s.evicted_blocks, 2);
        assert_eq!(s.invalidated_blocks, 5);
    }
}

"""Layer-1 Pallas kernel: the paper's **attention pipeline** (§3.4, §4.2, §4.4).

Single-token decode attention over a *quantized* KV history. Structure maps
the paper's mechanisms onto the TPU model (DESIGN.md §Hardware-Adaptation):

* **Arbitrary Q/K/V precision combinations** — one kernel body parameterized
  over KV16 / KV8 / KV4; Q stays full precision and is aligned to the K tile
  layout once per head by the BlockSpec index map (the §4.2 adaptive head
  alignment: alignment is a *load-layout* decision, not an extra dequant
  pass over the KV cache).
* **KV memory loading pipeline (§4.4)** — the kernel streams the KV history
  in 64-token macro-tiles (Figure 10) with an online-softmax accumulator;
  dequantization (I2F + scale FMA) happens per-tile between the load and
  the MXU contraction, and the Pallas grid pipeline overlaps the next
  tile's HBM→VMEM DMA with current compute.
* **GQA routing** — grid programs are (batch, query-head); the index map
  folds the query head onto its KV head, so no repeated-KV materialization.

Runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Macro-tile size of the KV loading pipeline (paper Figure 10: 64-token
# macro-tiles processed as 16-value micro-tiles; interpret mode models the
# macro level).
KV_TILE = 64


def _deq_tile(kind: str, k_tile, scale_tile):
    """Dequantize one KV tile. ``k_tile``: [TC, D] codes (or [TC, D/2] packed
    for int4, or f32 for kv16); ``scale_tile``: [TC] f32."""
    if kind == "f32":
        return k_tile
    if kind == "int8":
        return k_tile.astype(jnp.float32) * scale_tile[:, None]
    if kind == "int4":
        lo = (k_tile & 0x0F).astype(jnp.int32)
        hi = (k_tile >> 4).astype(jnp.int32)
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        tc, d2 = k_tile.shape
        codes = jnp.stack([lo, hi], axis=-1).reshape(tc, d2 * 2)
        return codes.astype(jnp.float32) * scale_tile[:, None]
    raise ValueError(kind)


def _attn_decode_kernel(q_ref, k_ref, ks_ref, v_ref, vs_ref, len_ref, o_ref,
                        *, kind: str, t_pad: int, d: int):
    """One (batch, head) program: stream KV tiles with online softmax."""
    q = q_ref[0, 0, :]  # [D]
    kv_len = len_ref[0]
    scale = 1.0 / (d ** 0.5)

    n_tiles = t_pad // KV_TILE

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        ts = i * KV_TILE
        k_tile = pl.load(k_ref, (0, 0, pl.dslice(ts, KV_TILE), slice(None)))
        ks_tile = pl.load(ks_ref, (0, 0, pl.dslice(ts, KV_TILE)))
        v_tile = pl.load(v_ref, (0, 0, pl.dslice(ts, KV_TILE), slice(None)))
        vs_tile = pl.load(vs_ref, (0, 0, pl.dslice(ts, KV_TILE)))

        # I2F + scale FMA on the tile already in VMEM — overlapped with the
        # next tile's DMA by the pipeline.
        k_f = _deq_tile(kind, k_tile, ks_tile)  # [TC, D]
        v_f = _deq_tile(kind, v_tile, vs_tile)

        s = (k_f @ q) * scale  # [TC]
        mask = (ts + jax.lax.iota(jnp.int32, KV_TILE)) < kv_len
        s = jnp.where(mask, s, -1e30)

        m_new = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # [TC]
        l_new = l_prev * alpha + p.sum()
        acc_new = acc_prev * alpha + p @ v_f  # [D]
        return m_new, l_new, acc_new

    m0 = jnp.float32(-1e30)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_tiles, body, (m0, l0, acc0))
    # kv_len >= 1 always holds on the decode path (the prompt has at least
    # one token); guard anyway so padding-only programs emit zeros.
    o_ref[0, 0, :] = jnp.where(l > 0, acc / l, 0.0)


def _attention_decode(q, k, ks, v, vs, kv_len, *, kind: str):
    b, h, d = q.shape
    hkv, t_pad = k.shape[1], k.shape[2]
    group = h // hkv
    assert t_pad % KV_TILE == 0, f"T={t_pad} must be a multiple of {KV_TILE}"
    kd = k.shape[3]  # D or D/2 (int4-packed)

    grid = (b, h)
    return pl.pallas_call(
        functools.partial(_attn_decode_kernel, kind=kind, t_pad=t_pad, d=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
            # §4.2: the Q-head program indexes its KV head directly — the
            # "head alignment" is baked into the load layout.
            pl.BlockSpec((1, 1, t_pad, kd), lambda i, j, g=group: (i, j // g, 0, 0)),
            pl.BlockSpec((1, 1, t_pad), lambda i, j, g=group: (i, j // g, 0)),
            pl.BlockSpec((1, 1, t_pad, kd), lambda i, j, g=group: (i, j // g, 0, 0)),
            pl.BlockSpec((1, 1, t_pad), lambda i, j, g=group: (i, j // g, 0)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), jnp.float32),
        interpret=True,
    )(q, k, ks, v, vs, kv_len)


@jax.jit
def attention_decode_kv16(q, k, v, kv_len):
    """Full-precision KV decode attention.

    q ``[B, H, D]`` f32; k, v ``[B, Hkv, T, D]`` f32; kv_len ``[B]`` i32.
    """
    dummy = jnp.ones(k.shape[:3], jnp.float32)
    return _attention_decode(q, k, dummy, v, dummy, kv_len, kind="f32")


@jax.jit
def attention_decode_kv8(q, k_q, k_scale, v_q, v_scale, kv_len):
    """INT8-KV decode attention: k_q/v_q ``[B, Hkv, T, D]`` int8 codes with
    per-(token, head) scales ``[B, Hkv, T]`` f32."""
    return _attention_decode(q, k_q, k_scale, v_q, v_scale, kv_len, kind="int8")


@jax.jit
def attention_decode_kv4(q, k_p, k_scale, v_p, v_scale, kv_len):
    """INT4-KV decode attention: k_p/v_p ``[B, Hkv, T, D/2]`` packed uint8."""
    return _attention_decode(q, k_p, k_scale, v_p, v_scale, kv_len, kind="int4")

"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references the pytest suite checks ``mp_gemm`` and
``mp_attention`` against (``assert_allclose``); they implement the same
mixed-precision math with no tiling, no pipelines, no tricks.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def dequant_w4(w_packed: jnp.ndarray, scales: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """Dequantize K-packed INT4 weights: ``[K/2, N]`` u8 + ``[K/G, N]`` f32 → ``[K, N]`` f32."""
    lo = (w_packed & 0x0F).astype(jnp.int32)
    hi = (w_packed >> 4).astype(jnp.int32)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    k2, n = w_packed.shape
    codes = jnp.stack([lo, hi], axis=1).reshape(k2 * 2, n).astype(jnp.float32)
    s = jnp.repeat(scales, group_size, axis=0)
    return codes * s


def gemm_w4_ref(x, w_packed, scales, group_size: int):
    """Reference W4A16 GEMM: dequantize then matmul. ``x: [M, K] f32``."""
    w = dequant_w4(w_packed, scales, group_size)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def gemm_w8_ref(x, w_codes, scales, group_size: int):
    """Reference W8A16 GEMM. ``w_codes: [K, N] int8``."""
    s = jnp.repeat(scales, group_size, axis=0)
    w = w_codes.astype(jnp.float32) * s
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dequant_kv_int8(kv_q, kv_scale):
    """``[..., T, D] int8`` codes × ``[..., T]`` scales → f32."""
    return kv_q.astype(jnp.float32) * kv_scale[..., None]


def dequant_kv_int4(kv_packed, kv_scale):
    """``[..., T, D/2] uint8`` packed codes × ``[..., T]`` scales → ``[..., T, D]`` f32."""
    lo = (kv_packed & 0x0F).astype(jnp.int32)
    hi = (kv_packed >> 4).astype(jnp.int32)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    codes = jnp.stack([lo, hi], axis=-1).reshape(
        kv_packed.shape[:-1] + (kv_packed.shape[-1] * 2,)
    )
    return codes.astype(jnp.float32) * kv_scale[..., None]


def softmax_lastdim(s):
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    return p / p.sum(axis=-1, keepdims=True)


def attention_decode_ref(q, k, v, kv_len):
    """Reference single-token decode attention with a length mask.

    q: ``[B, H, D]`` f32 — current-token queries.
    k, v: ``[B, Hkv, T, D]`` f32 — (dequantized) KV history, padded to T.
    kv_len: ``[B]`` int32 — valid history length per sequence.
    Returns ``[B, H, D]``.
    """
    b, h, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    t = k.shape[2]
    kg = jnp.repeat(k, group, axis=1)  # [B, H, T, D]
    vg = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhd,bhtd->bht", q, kg) / np.float32(np.sqrt(d))
    mask = jnp.arange(t)[None, None, :] < kv_len[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = softmax_lastdim(s)
    return jnp.einsum("bht,bhtd->bhd", p, vg)


def attention_prefill_ref(q, k, v, past_k, past_v, past_len):
    """Reference chunked-prefill attention: causal within the chunk plus
    full attention to the (dequantized) past context.

    q: ``[S, H, D]``; k, v: ``[S, Hkv, D]`` f32 for the current chunk.
    past_k, past_v: ``[Hkv, T, D]`` f32 padded history; ``past_len`` valid.
    Returns ``[S, H, D]``.
    """
    s_len, h, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    t = past_k.shape[1]

    kg = jnp.repeat(k, group, axis=1)  # [S, H, D]
    vg = jnp.repeat(v, group, axis=1)
    pkg = jnp.repeat(past_k, group, axis=0)  # [H, T, D]
    pvg = jnp.repeat(past_v, group, axis=0)

    scale = np.float32(1.0 / np.sqrt(d))
    s_past = jnp.einsum("shd,htd->sht", q, pkg) * scale  # [S, H, T]
    s_cur = jnp.einsum("shd,thd->sht", q, kg) * scale  # [S, H, S]

    past_mask = jnp.arange(t)[None, None, :] < past_len
    s_past = jnp.where(past_mask, s_past, -jnp.inf)
    causal = jnp.arange(s_len)[:, None] >= jnp.arange(s_len)[None, :]
    s_cur = jnp.where(causal[:, None, :], s_cur, -jnp.inf)

    s_all = jnp.concatenate([s_past, s_cur], axis=-1)
    p = softmax_lastdim(s_all)
    p_past, p_cur = p[..., :t], p[..., t:]
    out = jnp.einsum("sht,htd->shd", p_past, pvg) + jnp.einsum("sht,thd->shd", p_cur, vg)
    return out

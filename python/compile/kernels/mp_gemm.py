"""Layer-1 Pallas kernel: the paper's **GEMM pipeline** (§3.4, §4.1, §4.3).

Mixed-precision GEMM with dequantization *fused into the kernel body*: the
quantized weight block is DMA'd HBM→VMEM by the Pallas grid pipeline, the
Integer-to-Float (I2F) conversion + scale FMA runs between the copy and the
MXU contraction, and the next block's DMA overlaps the current compute —
the TPU analogue of the paper's three-way cp.async / I2F / mma.sync overlap
(Figure 9, DESIGN.md §Hardware-Adaptation).

Layout notes (the §4.1 analogue): weights arrive in the *offline-packed*
K-major layout produced by ``quantize.pack_int4_along_k`` — each VMEM block
``[K, bn]`` is one contiguous DMA, no gather, no runtime swizzle. Tiles are
sized in multiples of 128 along N so the MXU sees aligned operands
(Challenge-V analogue).

All kernels run under ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Performance on real hardware
is estimated from the BlockSpec structure in DESIGN.md, not measured here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. N is tiled in multiples of 128 (MXU lane width);
# M tiles stay small because serving decode batches are small.
BLOCK_M = 8
BLOCK_N = 256


def _gemm_w4_kernel(x_ref, w_ref, s_ref, o_ref, *, group_size: int):
    """One (M-tile, N-tile) program: dequant W4 block then contract.

    x_ref: ``[bm, K]`` f32 activations.
    w_ref: ``[K/2, bn]`` uint8 packed INT4 (K-major, offline-packed).
    s_ref: ``[K/G, bn]`` f32 groupwise scales.
    o_ref: ``[bm, bn]`` f32 out.
    """
    w_packed = w_ref[...]
    # I2F: nibble extraction + sign-extension (the lop3 idiom's effect).
    lo = (w_packed & 0x0F).astype(jnp.int32)
    hi = (w_packed >> 4).astype(jnp.int32)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    k2, bn = w_packed.shape
    codes = jnp.stack([lo, hi], axis=1).reshape(k2 * 2, bn).astype(jnp.float32)
    # FMA: apply groupwise scales (broadcast each scale row over its group).
    scales = jnp.repeat(s_ref[...], group_size, axis=0)
    w = codes * scales
    # MXU contraction on the dequantized block.
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _gemm_w8_kernel(x_ref, w_ref, s_ref, o_ref, *, group_size: int):
    """W8A16 variant: ``w_ref [K, bn]`` int8 codes."""
    codes = w_ref[...].astype(jnp.float32)
    scales = jnp.repeat(s_ref[...], group_size, axis=0)
    o_ref[...] = jnp.dot(
        x_ref[...], codes * scales, preferred_element_type=jnp.float32
    )


def _block(m: int, bm: int) -> int:
    """Largest tile ≤ bm that divides m (grids must tile exactly)."""
    b = min(bm, m)
    while m % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("group_size", "block_m", "block_n"))
def gemm_w4(x, w_packed, scales, *, group_size: int = 64,
            block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """W4A16 groupwise GEMM. ``x [M, K] f32``, ``w_packed [K/2, N] u8``,
    ``scales [K/G, N] f32`` → ``[M, N] f32``.

    Grid: (M/bm, N/bn). The full K extent rides inside each block — K per
    projection in the served models is ≤ a few thousand, so an
    ``[K, bn]``-sized weight block stays well under the 16 MB VMEM budget
    (DESIGN.md §Perf).
    """
    m, k = x.shape
    k2, n = w_packed.shape
    assert k == k2 * 2, f"packed K mismatch: {k} vs {k2}*2"
    assert k % group_size == 0
    bm, bn = _block(m, block_m), _block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_gemm_w4_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k2, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k // group_size, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_packed, scales)


@functools.partial(jax.jit, static_argnames=("group_size", "block_m", "block_n"))
def gemm_w8(x, w_codes, scales, *, group_size: int = 64,
            block_m: int = BLOCK_M, block_n: int = BLOCK_N):
    """W8A16 groupwise GEMM. ``w_codes [K, N] int8``."""
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2
    assert k % group_size == 0
    bm, bn = _block(m, block_m), _block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_gemm_w8_kernel, group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k // group_size, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w_codes, scales)

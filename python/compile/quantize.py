"""Quantization utilities — the build-time mirror of ``rust/src/quant``.

Groupwise symmetric weight quantization (AWQ/GPTQ-style) and per-token KV
quantization, using the exact same code/scale conventions as the Rust side so
the paged KV pool (Rust) and the Pallas kernels (here) agree bit-for-bit:

* weights: ``[K, N]``, groups of ``group_size`` rows share one scale per
  column; INT4 codes clamp to [-7, 7]; packing along **K** puts row ``2k`` in
  the low nibble and row ``2k+1`` in the high nibble of byte ``[k, n]``.
* KV rows: one symmetric scale per (token, kv-head); INT8 clamps to
  [-127, 127]; INT4 packs along the head dim, low nibble = even element.
"""

from __future__ import annotations

import numpy as np

# Default AWQ-style group size used across the stack.
GROUP_SIZE = 64


def quantize_groupwise_int4(w: np.ndarray, group_size: int = GROUP_SIZE):
    """Quantize ``[K, N]`` f32 weights to INT4 codes + per-group scales.

    Returns ``(codes, scales)`` where ``codes`` is int8 ``[K, N]`` in
    [-7, 7] and ``scales`` is f32 ``[K/group_size, N]``.
    """
    k, n = w.shape
    assert k % group_size == 0, f"group_size {group_size} must divide K={k}"
    grouped = w.reshape(k // group_size, group_size, n)
    maxabs = np.abs(grouped).max(axis=1)
    scales = np.where(maxabs > 0, maxabs / 7.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(grouped / scales[:, None, :]), -7, 7).astype(np.int8)
    return codes.reshape(k, n), scales


def quantize_groupwise_int8(w: np.ndarray, group_size: int = GROUP_SIZE):
    """INT8 variant of :func:`quantize_groupwise_int4` (codes in [-127, 127])."""
    k, n = w.shape
    assert k % group_size == 0
    grouped = w.reshape(k // group_size, group_size, n)
    maxabs = np.abs(grouped).max(axis=1)
    scales = np.where(maxabs > 0, maxabs / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(grouped / scales[:, None, :]), -127, 127).astype(np.int8)
    return codes.reshape(k, n), scales


def pack_int4_along_k(codes: np.ndarray) -> np.ndarray:
    """Pack INT4 codes ``[K, N]`` two-per-byte along K → uint8 ``[K/2, N]``.

    Row ``2k`` lands in the low nibble, row ``2k+1`` in the high nibble —
    the layout ``kernels.mp_gemm`` unpacks inside the Pallas kernel.
    """
    k, n = codes.shape
    assert k % 2 == 0
    u = codes.astype(np.uint8) & 0x0F
    return (u[0::2] | (u[1::2] << 4)).astype(np.uint8)


def unpack_int4_along_k(packed: np.ndarray) -> np.ndarray:
    """Inverse of :func:`pack_int4_along_k` → int8 codes ``[K, N]``."""
    k2, n = packed.shape
    lo = (packed & 0x0F).astype(np.int8)
    hi = (packed >> 4).astype(np.int8)
    lo = np.where(lo > 7, lo - 16, lo)
    hi = np.where(hi > 7, hi - 16, hi)
    out = np.empty((k2 * 2, n), dtype=np.int8)
    out[0::2] = lo
    out[1::2] = hi
    return out


def dequantize_groupwise(codes: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Dequantize ``[K, N]`` codes with ``[K/G, N]`` scales back to f32."""
    k, n = codes.shape
    g = k // scales.shape[0]
    return (codes.reshape(-1, g, n) * scales[:, None, :]).reshape(k, n).astype(np.float32)


# ---- KV cache quantization (per-token, per-head) --------------------------


def quantize_kv_int8(rows: np.ndarray):
    """Quantize KV rows ``[..., D]`` to INT8 with one scale per row.

    Returns ``(codes int8 [..., D], scales f32 [...])``.
    """
    maxabs = np.abs(rows).max(axis=-1)
    scales = np.where(maxabs > 0, maxabs / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(rows / scales[..., None]), -127, 127).astype(np.int8)
    return codes, scales


def quantize_kv_int4(rows: np.ndarray):
    """Quantize KV rows ``[..., D]`` to packed INT4 (two per byte along D).

    Returns ``(packed uint8 [..., D/2], scales f32 [...])``.
    """
    assert rows.shape[-1] % 2 == 0
    maxabs = np.abs(rows).max(axis=-1)
    scales = np.where(maxabs > 0, maxabs / 7.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(rows / scales[..., None]), -7, 7).astype(np.int8)
    u = codes.astype(np.uint8) & 0x0F
    packed = (u[..., 0::2] | (u[..., 1::2] << 4)).astype(np.uint8)
    return packed, scales


def dequantize_kv_int8(codes: np.ndarray, scales: np.ndarray) -> np.ndarray:
    return (codes.astype(np.float32) * scales[..., None]).astype(np.float32)


def dequantize_kv_int4(packed: np.ndarray, scales: np.ndarray) -> np.ndarray:
    lo = (packed & 0x0F).astype(np.int8)
    hi = (packed >> 4).astype(np.int8)
    lo = np.where(lo > 7, lo - 16, lo)
    hi = np.where(hi > 7, hi - 16, hi)
    d2 = packed.shape[-1]
    out = np.empty(packed.shape[:-1] + (d2 * 2,), dtype=np.float32)
    out[..., 0::2] = lo
    out[..., 1::2] = hi
    return out * scales[..., None]

"""Layer-2: the served transformer, written in JAX over the Layer-1 kernels.

A Qwen-shaped decoder-only GQA transformer (RMSNorm, RoPE, SwiGLU) with two
AOT-compiled graph families:

* ``prefill`` — processes one prompt chunk (batch 1, chunked Sarathi-style),
  attending causally within the chunk and fully to the *quantized* past
  context; returns last-position logits plus the chunk's quantized KV for
  the Rust pool to store.
* ``decode_step`` — one token for a batch of sequences; quantizes the new
  K/V in-graph (so the codes the Rust pool stores are exactly the codes the
  kernel will later consume), scatters them into the padded cache view, and
  runs the Layer-1 quantized-KV attention kernel.

Weight precision variants: ``w16`` (f32 stand-in for FP16) and ``w4``
(groupwise INT4 via the Layer-1 GEMM pipeline kernel). KV precision
variants: ``kv16`` / ``kv8`` / ``kv4``.

Python here runs only at ``make artifacts`` time; the graphs are lowered to
HLO text and executed from Rust via PJRT.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import mp_attention, mp_gemm
from . import quantize as Q

RMS_EPS = 1e-5
ROPE_THETA = 10000.0


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Architecture hyperparameters (mirror of Rust ``ModelConfig::tiny``)."""

    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 768
    vocab_size: int = 2048
    max_seq_len: int = 512
    group_size: int = 64

    @property
    def q_out(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_out(self) -> int:
        return self.n_kv_heads * self.head_dim


# The seven per-layer projection matrices, with (in_dim, out_dim) getters.
PROJS = (
    ("wq", lambda s: (s.d_model, s.q_out)),
    ("wk", lambda s: (s.d_model, s.kv_out)),
    ("wv", lambda s: (s.d_model, s.kv_out)),
    ("wo", lambda s: (s.q_out, s.d_model)),
    ("w_gate", lambda s: (s.d_model, s.d_ff)),
    ("w_up", lambda s: (s.d_model, s.d_ff)),
    ("w_down", lambda s: (s.d_ff, s.d_model)),
)


def init_params(spec: ModelSpec, seed: int = 0) -> dict[str, np.ndarray]:
    """Deterministic synthetic weights (layer-stacked), float32.

    Scaled-down Xavier-ish init so activations stay O(1) through the stack —
    the substitution for a real checkpoint (DESIGN.md §1).
    """
    rng = np.random.default_rng(seed)

    def mat(shape, fan_in):
        return (rng.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32)

    p: dict[str, np.ndarray] = {
        "embed": mat((spec.vocab_size, spec.d_model), spec.d_model),
        "final_norm": np.ones(spec.d_model, np.float32),
        "lm_head": mat((spec.d_model, spec.vocab_size), spec.d_model),
        "attn_norm": np.ones((spec.n_layers, spec.d_model), np.float32),
        "ffn_norm": np.ones((spec.n_layers, spec.d_model), np.float32),
    }
    for name, dims in PROJS:
        k, n = dims(spec)
        p[name] = np.stack([mat((k, n), k) for _ in range(spec.n_layers)])
    return p


def quantize_params_w4(spec: ModelSpec, params: dict[str, np.ndarray]):
    """Groupwise-INT4 quantize the seven projections (embeddings, norms and
    the LM head stay full precision, the standard W4A16 recipe)."""
    out: dict[str, np.ndarray] = {
        k: params[k] for k in ("embed", "final_norm", "lm_head", "attn_norm", "ffn_norm")
    }
    for name, _ in PROJS:
        packs, scales = [], []
        for l in range(spec.n_layers):
            codes, s = Q.quantize_groupwise_int4(params[name][l], spec.group_size)
            packs.append(Q.pack_int4_along_k(codes))
            scales.append(s)
        out[name + "_p"] = np.stack(packs)
        out[name + "_s"] = np.stack(scales)
    return out


def weight_input_names(wprec: str) -> list[str]:
    """Canonical weight-argument order for the AOT graphs (recorded in the
    manifest; the Rust runtime feeds buffers in exactly this order)."""
    names = ["embed", "attn_norm", "ffn_norm", "final_norm", "lm_head"]
    for name, _ in PROJS:
        if wprec == "w4":
            names += [name + "_p", name + "_s"]
        else:
            names.append(name)
    return names


# ---- building blocks -------------------------------------------------------


def rmsnorm(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS) * g


def rope(x, positions, head_dim: int):
    """Rotary embedding, half-split convention. ``x: [..., n_heads, D]``,
    ``positions: [...]`` (one position per leading index)."""
    half = head_dim // 2
    freqs = ROPE_THETA ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _proj(x, weights, name, layer, wprec, group_size):
    """Project ``x [M, K]`` with layer ``layer``'s ``name`` matrix, through
    the Layer-1 GEMM pipeline kernel when quantized."""
    if wprec == "w4":
        return mp_gemm.gemm_w4(
            x, weights[name + "_p"][layer], weights[name + "_s"][layer],
            group_size=group_size,
        )
    return jnp.dot(x, weights[name][layer], preferred_element_type=jnp.float32)


def silu(x):
    return x * jax.nn.sigmoid(x)


def _quantize_kv_ingraph(x, kvprec: str):
    """Quantize new KV rows inside the graph so pool codes == kernel codes.

    ``x: [..., D]`` → (codes, scales) matching ``quantize.quantize_kv_*``.
    """
    maxabs = jnp.max(jnp.abs(x), axis=-1)
    if kvprec == "kv8":
        scale = jnp.where(maxabs > 0, maxabs / 127.0, 1.0)
        codes = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
        return codes, scale.astype(jnp.float32)
    if kvprec == "kv4":
        scale = jnp.where(maxabs > 0, maxabs / 7.0, 1.0)
        c = jnp.clip(jnp.round(x / scale[..., None]), -7, 7).astype(jnp.int32)
        u = c.astype(jnp.uint8) & 0x0F
        packed = u[..., 0::2] | (u[..., 1::2] << 4)
        return packed.astype(jnp.uint8), scale.astype(jnp.float32)
    raise ValueError(kvprec)


# ---- decode step -----------------------------------------------------------


def make_decode_step(spec: ModelSpec, wprec: str, kvprec: str):
    """Build the single-step decode function for a (weight, kv) precision
    pair. Signature (positional, AOT-friendly):

    ``fn(tokens[B] i32, kv_len[B] i32, kv_k, kv_ks, kv_v, kv_vs, *weights)``

    kv16: ``kv_k/v [L,B,Hkv,T,D] f32``; ``kv_ks/vs [L,B,Hkv,T] f32`` (unused
    dummies kept for a uniform signature).
    kv8:  codes int8 + scales. kv4: packed uint8 ``[...,D/2]`` + scales.

    Returns ``(logits [B,V], k_new, k_new_scale, v_new, v_new_scale)`` where
    ``k_new/v_new`` are quantized codes ``[L,B,Hkv,D(/2)]`` (f32 for kv16)
    and scales are ``[L,B,Hkv]`` (dummy ones for kv16).
    """
    wnames = weight_input_names(wprec)

    def step(tokens, kv_len, kv_k, kv_ks, kv_v, kv_vs, *wflat):
        weights = dict(zip(wnames, wflat))
        b = tokens.shape[0]
        x = jnp.take(weights["embed"], tokens, axis=0)  # [B, D]

        new_ks, new_kss, new_vs_, new_vss = [], [], [], []
        for l in range(spec.n_layers):
            h = rmsnorm(x, weights["attn_norm"][l])
            q = _proj(h, weights, "wq", l, wprec, spec.group_size)
            k = _proj(h, weights, "wk", l, wprec, spec.group_size)
            v = _proj(h, weights, "wv", l, wprec, spec.group_size)
            q = q.reshape(b, spec.n_heads, spec.head_dim)
            k = k.reshape(b, spec.n_kv_heads, spec.head_dim)
            v = v.reshape(b, spec.n_kv_heads, spec.head_dim)
            q = rope(q, kv_len, spec.head_dim)  # new token sits at index kv_len
            k = rope(k, kv_len, spec.head_dim)

            if kvprec == "kv16":
                k_store, k_scale = k, jnp.ones((b, spec.n_kv_heads), jnp.float32)
                v_store, v_scale = v, jnp.ones((b, spec.n_kv_heads), jnp.float32)
            else:
                k_store, k_scale = _quantize_kv_ingraph(k, kvprec)
                v_store, v_scale = _quantize_kv_ingraph(v, kvprec)

            # Scatter the new row into the padded cache view at kv_len[b].
            def ins_row(cache, row, idx):
                return jax.lax.dynamic_update_slice(cache, row[:, None, :], (0, idx, 0))

            def ins_scale(cache, s, idx):
                return jax.lax.dynamic_update_slice(cache, s[:, None], (0, idx))

            k_cache = jax.vmap(ins_row)(kv_k[l], k_store, kv_len)
            v_cache = jax.vmap(ins_row)(kv_v[l], v_store, kv_len)
            ks_cache = jax.vmap(ins_scale)(kv_ks[l], k_scale, kv_len)
            vs_cache = jax.vmap(ins_scale)(kv_vs[l], v_scale, kv_len)

            attn_len = kv_len + 1
            if kvprec == "kv16":
                o = mp_attention.attention_decode_kv16(q, k_cache, v_cache, attn_len)
            elif kvprec == "kv8":
                o = mp_attention.attention_decode_kv8(
                    q, k_cache, ks_cache, v_cache, vs_cache, attn_len)
            else:
                o = mp_attention.attention_decode_kv4(
                    q, k_cache, ks_cache, v_cache, vs_cache, attn_len)

            o = o.reshape(b, spec.q_out)
            x = x + _proj(o, weights, "wo", l, wprec, spec.group_size)

            h2 = rmsnorm(x, weights["ffn_norm"][l])
            gate = _proj(h2, weights, "w_gate", l, wprec, spec.group_size)
            up = _proj(h2, weights, "w_up", l, wprec, spec.group_size)
            x = x + _proj(silu(gate) * up, weights, "w_down", l, wprec, spec.group_size)

            new_ks.append(k_store)
            new_kss.append(k_scale)
            new_vs_.append(v_store)
            new_vss.append(v_scale)

        x = rmsnorm(x, weights["final_norm"])
        logits = jnp.dot(x, weights["lm_head"], preferred_element_type=jnp.float32)
        return (
            logits,
            jnp.stack(new_ks),
            jnp.stack(new_kss),
            jnp.stack(new_vs_),
            jnp.stack(new_vss),
        )

    return step


# ---- prefill ---------------------------------------------------------------


def make_prefill(spec: ModelSpec, wprec: str, kvprec: str):
    """Build the chunked prefill function (batch 1).

    ``fn(tokens[S] i32, past_len[1] i32, kv_k, kv_ks, kv_v, kv_vs, *weights)``

    Past caches have batch dim 1: kv16 ``[L,1,Hkv,T,D]`` f32; kv8/kv4 codes
    plus ``[L,1,Hkv,T]`` scales. Returns ``(logits[S,V], k_chunk, k_scales,
    v_chunk, v_scales)`` with ``k_chunk [L,Hkv,S,D(/2)]`` quantized codes
    (f32 for kv16) and scales ``[L,Hkv,S]``.

    Logits cover **every** chunk position: prompts rarely fill a compiled
    chunk bucket exactly, so the engine pads the tail and reads the logits
    row of the last *real* token (causality makes the padding harmless).
    """
    wnames = weight_input_names(wprec)

    from .kernels import ref as R

    def dequant_past(kv, ks):
        if kvprec == "kv16":
            return kv
        if kvprec == "kv8":
            return R.dequant_kv_int8(kv, ks)
        return R.dequant_kv_int4(kv, ks)

    def prefill(tokens, past_len, kv_k, kv_ks, kv_v, kv_vs, *wflat):
        weights = dict(zip(wnames, wflat))
        s_len = tokens.shape[0]
        p0 = past_len[0]
        x = jnp.take(weights["embed"], tokens, axis=0)  # [S, D]
        positions = p0 + jnp.arange(s_len, dtype=jnp.int32)

        from .kernels import ref as R

        out_k, out_ks, out_v, out_vs = [], [], [], []
        for l in range(spec.n_layers):
            h = rmsnorm(x, weights["attn_norm"][l])
            q = _proj(h, weights, "wq", l, wprec, spec.group_size)
            k = _proj(h, weights, "wk", l, wprec, spec.group_size)
            v = _proj(h, weights, "wv", l, wprec, spec.group_size)
            q = q.reshape(s_len, spec.n_heads, spec.head_dim)
            k = k.reshape(s_len, spec.n_kv_heads, spec.head_dim)
            v = v.reshape(s_len, spec.n_kv_heads, spec.head_dim)
            q = rope(q, positions, spec.head_dim)
            k = rope(k, positions, spec.head_dim)

            past_k = dequant_past(kv_k[l, 0], kv_ks[l, 0])  # [Hkv, T, D]
            past_v = dequant_past(kv_v[l, 0], kv_vs[l, 0])
            o = R.attention_prefill_ref(q, k, v, past_k, past_v, p0)

            o = o.reshape(s_len, spec.q_out)
            x = x + _proj(o, weights, "wo", l, wprec, spec.group_size)

            h2 = rmsnorm(x, weights["ffn_norm"][l])
            gate = _proj(h2, weights, "w_gate", l, wprec, spec.group_size)
            up = _proj(h2, weights, "w_up", l, wprec, spec.group_size)
            x = x + _proj(silu(gate) * up, weights, "w_down", l, wprec, spec.group_size)

            # Quantize the chunk's KV for storage ([Hkv, S, D] layout).
            k_t = k.transpose(1, 0, 2)
            v_t = v.transpose(1, 0, 2)
            if kvprec == "kv16":
                out_k.append(k_t)
                out_ks.append(jnp.ones((spec.n_kv_heads, s_len), jnp.float32))
                out_v.append(v_t)
                out_vs.append(jnp.ones((spec.n_kv_heads, s_len), jnp.float32))
            else:
                kc, ks_ = _quantize_kv_ingraph(k_t, kvprec)
                vc, vs_ = _quantize_kv_ingraph(v_t, kvprec)
                out_k.append(kc)
                out_ks.append(ks_)
                out_v.append(vc)
                out_vs.append(vs_)

        x = rmsnorm(x, weights["final_norm"])
        logits = jnp.dot(x, weights["lm_head"], preferred_element_type=jnp.float32)
        return (
            logits,
            jnp.stack(out_k),
            jnp.stack(out_ks),
            jnp.stack(out_v),
            jnp.stack(out_vs),
        )

    return prefill


# ---- shape helpers shared with aot.py --------------------------------------


def kv_cache_shapes(spec: ModelSpec, kvprec: str, batch: int, t_pad: int | None = None):
    """(kv_codes_shape, kv_scales_shape, codes_dtype) for the padded cache.

    ``t_pad`` defaults to the full context; decode graphs are also compiled
    at smaller context buckets (see aot.DECODE_T).
    """
    t = t_pad if t_pad is not None else spec.max_seq_len
    if kvprec == "kv16":
        return ((spec.n_layers, batch, spec.n_kv_heads, t, spec.head_dim),
                (spec.n_layers, batch, spec.n_kv_heads, t), jnp.float32)
    if kvprec == "kv8":
        return ((spec.n_layers, batch, spec.n_kv_heads, t, spec.head_dim),
                (spec.n_layers, batch, spec.n_kv_heads, t), jnp.int8)
    if kvprec == "kv4":
        return ((spec.n_layers, batch, spec.n_kv_heads, t, spec.head_dim // 2),
                (spec.n_layers, batch, spec.n_kv_heads, t), jnp.uint8)
    raise ValueError(kvprec)

"""AOT compilation: lower every served graph variant to HLO **text** and
emit the weight binaries + manifest the Rust runtime consumes.

Run once via ``make artifacts``; Python never runs on the request path.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifact matrix (DESIGN.md §2):

* decode graphs: ``w4 × {kv16, kv8, kv4}`` + ``w16 × kv16``, each at batch
  sizes {1, 2, 4, 8};
* prefill graphs: the same four precision pairs at chunk lengths {32, 128};
* microkernel graphs (integration-test fixtures): ``gemm_w4``, ``gemm_w8``,
  ``attn_kv8``, ``attn_kv4``.

Outputs in ``--out-dir``:
  ``<name>.hlo.txt`` per graph, ``weights_w16.bin`` / ``weights_w4.bin``
  (raw little-endian tensor concatenations), and ``manifest.json``
  describing graphs (input/output signatures) and weight tensor layouts.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import quantize as Q
from .kernels import mp_attention, mp_gemm

DECODE_BATCHES = (1, 2, 4, 8)
# Decode context buckets: the engine picks the smallest padded KV extent
# covering the batch's longest sequence, so short contexts do not pay for
# a full max_seq_len attention scan (§Perf).
DECODE_T = (128, 512)
PREFILL_CHUNKS = (32, 128)
# (weight precision, kv precision) pairs compiled for the engine.
VARIANTS = (("w4", "kv16"), ("w4", "kv8"), ("w4", "kv4"), ("w16", "kv16"))


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {
        jnp.float32.dtype: "f32",
        jnp.int32.dtype: "i32",
        jnp.int8.dtype: "i8",
        jnp.uint8.dtype: "u8",
    }[np.dtype(dt)]


def _spec(shape, dt):
    return jax.ShapeDtypeStruct(tuple(shape), dt)


def _input_entry(name, spec):
    return {"name": name, "dtype": _dtype_name(spec.dtype), "shape": list(spec.shape)}


def weight_arg_specs(spec: M.ModelSpec, wprec: str, params: dict):
    """(names, ShapeDtypeStructs) for the weight tail of every graph."""
    names = M.weight_input_names(wprec)
    specs = [_spec(params[n].shape, params[n].dtype) for n in names]
    return names, specs


def decode_arg_specs(spec: M.ModelSpec, kvprec: str, batch: int, t_pad: int):
    kshape, sshape, kdt = M.kv_cache_shapes(spec, kvprec, batch, t_pad)
    return [
        ("tokens", _spec((batch,), jnp.int32)),
        ("kv_len", _spec((batch,), jnp.int32)),
        ("kv_k", _spec(kshape, kdt)),
        ("kv_k_scale", _spec(sshape, jnp.float32)),
        ("kv_v", _spec(kshape, kdt)),
        ("kv_v_scale", _spec(sshape, jnp.float32)),
    ]


def prefill_arg_specs(spec: M.ModelSpec, kvprec: str, chunk: int):
    kshape, sshape, kdt = M.kv_cache_shapes(spec, kvprec, 1)
    return [
        ("tokens", _spec((chunk,), jnp.int32)),
        ("past_len", _spec((1,), jnp.int32)),
        ("kv_k", _spec(kshape, kdt)),
        ("kv_k_scale", _spec(sshape, jnp.float32)),
        ("kv_v", _spec(kshape, kdt)),
        ("kv_v_scale", _spec(sshape, jnp.float32)),
    ]


def lower_graph(fn, arg_specs, weight_specs):
    # keep_unused=True: the kv16 variants ignore the scale inputs, but the
    # Rust engine feeds a uniform signature — unused args must stay in the
    # compiled program's parameter list.
    args = [s for _, s in arg_specs] + list(weight_specs)
    return jax.jit(fn, keep_unused=True).lower(*args)


def write_weights_bin(path: str, names: list[str], params: dict) -> list[dict]:
    """Concatenate tensors (row-major, little-endian) and return the layout
    table for the manifest."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for n in names:
            arr = np.ascontiguousarray(params[n])
            raw = arr.tobytes()
            table.append({
                "name": n,
                "dtype": _dtype_name(arr.dtype),
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            })
            f.write(raw)
            offset += len(raw)
    return table


def microkernel_graphs(spec: M.ModelSpec):
    """Small standalone kernel graphs used by Rust integration tests."""
    m, k, n, g = 8, 256, 256, spec.group_size
    b, h, hkv, t, d = 2, spec.n_heads, spec.n_kv_heads, 128, spec.head_dim

    def gemm_w4(x, wp, s):
        return (mp_gemm.gemm_w4(x, wp, s, group_size=g),)

    def gemm_w8(x, wc, s):
        return (mp_gemm.gemm_w8(x, wc, s, group_size=g),)

    def attn_kv8(q, kq, ks, vq, vs, ln):
        return (mp_attention.attention_decode_kv8(q, kq, ks, vq, vs, ln),)

    def attn_kv4(q, kp, ks, vp, vs, ln):
        return (mp_attention.attention_decode_kv4(q, kp, ks, vp, vs, ln),)

    return {
        "kernel_gemm_w4": (gemm_w4, [
            ("x", _spec((m, k), jnp.float32)),
            ("w_packed", _spec((k // 2, n), jnp.uint8)),
            ("scales", _spec((k // g, n), jnp.float32)),
        ]),
        "kernel_gemm_w8": (gemm_w8, [
            ("x", _spec((m, k), jnp.float32)),
            ("w_codes", _spec((k, n), jnp.int8)),
            ("scales", _spec((k // g, n), jnp.float32)),
        ]),
        "kernel_attn_kv8": (attn_kv8, [
            ("q", _spec((b, h, d), jnp.float32)),
            ("k_q", _spec((b, hkv, t, d), jnp.int8)),
            ("k_scale", _spec((b, hkv, t), jnp.float32)),
            ("v_q", _spec((b, hkv, t, d), jnp.int8)),
            ("v_scale", _spec((b, hkv, t), jnp.float32)),
            ("kv_len", _spec((b,), jnp.int32)),
        ]),
        "kernel_attn_kv4": (attn_kv4, [
            ("q", _spec((b, h, d), jnp.float32)),
            ("k_p", _spec((b, hkv, t, d // 2), jnp.uint8)),
            ("k_scale", _spec((b, hkv, t), jnp.float32)),
            ("v_p", _spec((b, hkv, t, d // 2), jnp.uint8)),
            ("v_scale", _spec((b, hkv, t), jnp.float32)),
            ("kv_len", _spec((b,), jnp.int32)),
        ]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="only compile batch-1 decode + one prefill per variant")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    spec = M.ModelSpec()
    params16 = M.init_params(spec, seed=args.seed)
    params4 = M.quantize_params_w4(spec, params16)
    by_prec = {"w16": params16, "w4": params4}

    manifest: dict = {
        "model": {
            "name": "tiny-qwen",
            "n_layers": spec.n_layers,
            "d_model": spec.d_model,
            "n_heads": spec.n_heads,
            "n_kv_heads": spec.n_kv_heads,
            "head_dim": spec.head_dim,
            "d_ff": spec.d_ff,
            "vocab_size": spec.vocab_size,
            "max_seq_len": spec.max_seq_len,
            "group_size": spec.group_size,
            "seed": args.seed,
        },
        "decode_batches": list(DECODE_BATCHES),
        "decode_t": list(DECODE_T),
        "prefill_chunks": list(PREFILL_CHUNKS),
        "graphs": [],
        "weights": {},
    }

    # Weight binaries.
    for wprec, params in by_prec.items():
        names = M.weight_input_names(wprec)
        bin_name = f"weights_{wprec}.bin"
        table = write_weights_bin(os.path.join(args.out_dir, bin_name), names, params)
        manifest["weights"][wprec] = {"file": bin_name, "tensors": table}
        print(f"wrote {bin_name} ({sum(t['nbytes'] for t in table)} bytes)")

    batches = DECODE_BATCHES[:1] if args.quick else DECODE_BATCHES
    chunks = PREFILL_CHUNKS[:1] if args.quick else PREFILL_CHUNKS

    def emit(name: str, lowered, arg_specs, weight_names):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["graphs"].append({
            "name": name,
            "file": fname,
            "inputs": [_input_entry(n, s) for n, s in arg_specs],
            "weight_inputs": weight_names,
        })
        print(f"lowered {fname} ({len(text)} chars)")

    for wprec, kvprec in VARIANTS:
        params = by_prec[wprec]
        wnames, wspecs = weight_arg_specs(spec, wprec, params)
        for b in batches:
            for t_pad in DECODE_T:
                fn = M.make_decode_step(spec, wprec, kvprec)
                arg_specs = decode_arg_specs(spec, kvprec, b, t_pad)
                lowered = lower_graph(fn, arg_specs, wspecs)
                emit(f"decode_{wprec}_{kvprec}_b{b}_t{t_pad}", lowered, arg_specs, wnames)
        for s in chunks:
            fn = M.make_prefill(spec, wprec, kvprec)
            arg_specs = prefill_arg_specs(spec, kvprec, s)
            lowered = lower_graph(fn, arg_specs, wspecs)
            emit(f"prefill_{wprec}_{kvprec}_s{s}", lowered, arg_specs, wnames)

    for name, (fn, arg_specs) in microkernel_graphs(spec).items():
        lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in arg_specs])
        emit(name, lowered, arg_specs, [])

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['graphs'])} graphs")


if __name__ == "__main__":
    main()

"""Quantization round-trip and cross-language format tests.

The byte-level layout checks here pin the *exact* conventions the Rust side
(`rust/src/quant`) implements — low nibble = even index, symmetric clamp
ranges — so the two languages stay bit-compatible.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q


def rand(shape, seed=0, scale=2.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


class TestGroupwise:
    @pytest.mark.parametrize("group", [16, 32, 64])
    def test_int4_roundtrip_error_bound(self, group):
        w = rand((128, 32), seed=1)
        codes, scales = Q.quantize_groupwise_int4(w, group)
        deq = Q.dequantize_groupwise(codes, scales)
        bound = scales.max() * 0.5 * 1.001
        assert np.abs(w - deq).max() <= bound

    def test_int8_roundtrip_error_bound(self):
        w = rand((128, 32), seed=2)
        codes, scales = Q.quantize_groupwise_int8(w, 64)
        deq = Q.dequantize_groupwise(codes, scales)
        assert np.abs(w - deq).max() <= scales.max() * 0.5 * 1.001

    def test_int4_codes_in_range(self):
        w = rand((64, 16), seed=3, scale=100.0)
        codes, _ = Q.quantize_groupwise_int4(w, 64)
        assert codes.min() >= -7 and codes.max() <= 7

    def test_pack_unpack_identity(self):
        w = rand((64, 24), seed=4)
        codes, _ = Q.quantize_groupwise_int4(w, 32)
        packed = Q.pack_int4_along_k(codes)
        assert packed.shape == (32, 24)
        assert np.array_equal(Q.unpack_int4_along_k(packed), codes)

    def test_pack_nibble_convention(self):
        # Row 2k in low nibble, row 2k+1 in high nibble — the layout the
        # Pallas kernel and the Rust loader both assume.
        codes = np.zeros((2, 1), np.int8)
        codes[0, 0] = 3   # low
        codes[1, 0] = -2  # high: -2 & 0xF = 14
        packed = Q.pack_int4_along_k(codes)
        assert packed[0, 0] == (14 << 4) | 3

    def test_zero_weights_exact(self):
        w = np.zeros((64, 8), np.float32)
        codes, scales = Q.quantize_groupwise_int4(w, 64)
        assert np.array_equal(Q.dequantize_groupwise(codes, scales), w)

    def test_rejects_bad_group(self):
        with pytest.raises(AssertionError):
            Q.quantize_groupwise_int4(rand((100, 4)), 64)

    @settings(max_examples=25, deadline=None)
    @given(
        kg=st.integers(1, 4),
        n=st.integers(1, 48),
        group=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_roundtrip(self, kg, n, group, seed):
        w = rand((kg * group, n), seed=seed, scale=3.0)
        codes, scales = Q.quantize_groupwise_int4(w, group)
        deq = Q.dequantize_groupwise(codes, scales)
        assert np.abs(w - deq).max() <= scales.max() * 0.5 * 1.001


class TestKv:
    def test_int8_roundtrip(self):
        rows = rand((4, 8, 32), seed=5)
        codes, scales = Q.quantize_kv_int8(rows)
        deq = Q.dequantize_kv_int8(codes, scales)
        assert np.abs(rows - deq).max() <= scales.max() * 0.5 * 1.001

    def test_int4_roundtrip(self):
        rows = rand((4, 8, 32), seed=6)
        packed, scales = Q.quantize_kv_int4(rows)
        assert packed.shape == (4, 8, 16)
        deq = Q.dequantize_kv_int4(packed, scales)
        assert np.abs(rows - deq).max() <= scales.max() * 0.5 * 1.001

    def test_per_row_scales_independent(self):
        rows = np.stack([np.full(16, 0.1, np.float32), np.full(16, 50.0, np.float32)])
        _, scales = Q.quantize_kv_int8(rows)
        assert scales[0] < scales[1]

    def test_zero_rows(self):
        rows = np.zeros((2, 16), np.float32)
        codes, scales = Q.quantize_kv_int8(rows)
        assert np.array_equal(Q.dequantize_kv_int8(codes, scales), rows)
        packed, s4 = Q.quantize_kv_int4(rows)
        assert np.array_equal(Q.dequantize_kv_int4(packed, s4), rows)

    @settings(max_examples=25, deadline=None)
    @given(d=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_kv_roundtrip(self, d, seed):
        rows = rand((3, d), seed=seed, scale=5.0)
        codes, scales = Q.quantize_kv_int8(rows)
        assert np.abs(rows - Q.dequantize_kv_int8(codes, scales)).max() \
            <= scales.max() * 0.5 * 1.001
        packed, s4 = Q.quantize_kv_int4(rows)
        assert np.abs(rows - Q.dequantize_kv_int4(packed, s4)).max() \
            <= s4.max() * 0.5 * 1.001

"""Layer-2 model tests: shapes, determinism, prefill↔decode consistency, and
KV-precision accuracy ordering (the Table 1 "accuracy equivalence" primitive).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SPEC = M.ModelSpec(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                   d_ff=128, vocab_size=256, max_seq_len=128, group_size=32)


@pytest.fixture(scope="module")
def params16():
    return M.init_params(SPEC, seed=7)


@pytest.fixture(scope="module")
def params4(params16):
    return M.quantize_params_w4(SPEC, params16)


def wflat(params, wprec):
    return [jnp.array(params[n]) for n in M.weight_input_names(wprec)]


def empty_cache(kvprec, batch):
    kshape, sshape, kdt = M.kv_cache_shapes(SPEC, kvprec, batch)
    return (jnp.zeros(kshape, kdt), jnp.ones(sshape, jnp.float32),
            jnp.zeros(kshape, kdt), jnp.ones(sshape, jnp.float32))


def run_prefill(wprec, kvprec, weights, tokens):
    pre = jax.jit(M.make_prefill(SPEC, wprec, kvprec))
    kv_k, kv_ks, kv_v, kv_vs = empty_cache(kvprec, 1)
    return pre(jnp.asarray(tokens, jnp.int32), jnp.array([0], jnp.int32),
               kv_k, kv_ks, kv_v, kv_vs, *weights)


class TestShapes:
    def test_param_shapes(self, params16):
        assert params16["embed"].shape == (256, 64)
        assert params16["wq"].shape == (2, 64, 64)
        assert params16["w_down"].shape == (2, 128, 64)

    def test_quantized_param_shapes(self, params4):
        assert params4["wq_p"].shape == (2, 32, 64)   # K packed /2
        assert params4["wq_s"].shape == (2, 2, 64)    # K/group
        assert "wq" not in params4

    def test_weight_input_names_cover_params(self, params16, params4):
        for wprec, p in [("w16", params16), ("w4", params4)]:
            for n in M.weight_input_names(wprec):
                assert n in p, n

    def test_decode_output_shapes(self, params16):
        step = jax.jit(M.make_decode_step(SPEC, "w16", "kv16"))
        caches = empty_cache("kv16", 3)
        logits, knew, ksn, vnew, vsn = step(
            jnp.array([1, 2, 3], jnp.int32), jnp.array([0, 0, 0], jnp.int32),
            *caches, *wflat(params16, "w16"))
        assert logits.shape == (3, 256)
        assert knew.shape == (2, 3, 2, 16)
        assert ksn.shape == (2, 3, 2)

    def test_decode_kv4_packed_shapes(self, params4):
        step = jax.jit(M.make_decode_step(SPEC, "w4", "kv4"))
        caches = empty_cache("kv4", 1)
        _, knew, _, _, _ = step(jnp.array([1], jnp.int32), jnp.array([0], jnp.int32),
                                *caches, *wflat(params4, "w4"))
        assert knew.shape == (2, 1, 2, 8)  # D/2 packed
        assert knew.dtype == jnp.uint8


class TestConsistency:
    def test_deterministic(self, params16):
        a = run_prefill("w16", "kv16", wflat(params16, "w16"), np.arange(8))
        b = run_prefill("w16", "kv16", wflat(params16, "w16"), np.arange(8))
        np.testing.assert_array_equal(a[0], b[0])

    @pytest.mark.parametrize("wprec,kvprec", [("w16", "kv16"), ("w4", "kv8"), ("w4", "kv4")])
    def test_prefill_then_decode_matches_longer_prefill(self, params16, params4,
                                                        wprec, kvprec):
        """logits(prefill(t0..t7) → decode(t8)) ≈ logits(prefill(t0..t8)).

        The decode path sees *quantized* history for t0..t7 while the longer
        prefill sees exact f32 within the chunk, so tolerance scales with KV
        precision; kv16 must agree tightly.
        """
        weights = wflat(params4 if wprec == "w4" else params16, wprec)
        toks = np.arange(2, 11)  # 9 tokens

        # Path A: prefill 8, then decode token 9.
        plog, kc, kcs, vc, vcs = run_prefill(wprec, kvprec, weights, toks[:8])
        kv_k, kv_ks, kv_v, kv_vs = empty_cache(kvprec, 1)
        # Insert chunk KV [L,Hkv,S,*] at positions 0..7.
        kv_k = kv_k.at[:, 0, :, :8].set(kc)
        kv_v = kv_v.at[:, 0, :, :8].set(vc)
        kv_ks = kv_ks.at[:, 0, :, :8].set(kcs)
        kv_vs = kv_vs.at[:, 0, :, :8].set(vcs)
        step = jax.jit(M.make_decode_step(SPEC, wprec, kvprec))
        dlog, *_ = step(jnp.array([toks[8]], jnp.int32), jnp.array([8], jnp.int32),
                        kv_k, kv_ks, kv_v, kv_vs, *weights)

        # Path B: single 9-token prefill (read the last position's row).
        plog9, *_ = run_prefill(wprec, kvprec, weights, toks)

        # kv4 genuinely diverges: the decode path reads INT4-quantized
        # history for all prior tokens while the longer prefill sees them
        # exact — measured max |Δlogit| ≈ 0.42 on logits of scale ~2.8.
        tol = {"kv16": 1e-4, "kv8": 0.05, "kv4": 0.6}[kvprec]
        np.testing.assert_allclose(np.array(dlog[0]), np.array(plog9)[-1], atol=tol, rtol=0.05)

    def test_chunked_prefill_matches_single(self, params16):
        """prefill(t0..t3) then prefill(t4..t7 | past=4) ≈ prefill(t0..t7)."""
        weights = wflat(params16, "w16")
        toks = np.arange(3, 11)
        # Single shot.
        single, *_ = run_prefill("w16", "kv16", weights, toks)
        # Chunked.
        _, kc, kcs, vc, vcs = run_prefill("w16", "kv16", weights, toks[:4])
        kv_k, kv_ks, kv_v, kv_vs = empty_cache("kv16", 1)
        kv_k = kv_k.at[:, 0, :, :4].set(kc)
        kv_v = kv_v.at[:, 0, :, :4].set(vc)
        pre = jax.jit(M.make_prefill(SPEC, "w16", "kv16"))
        chunked, *_ = pre(jnp.asarray(toks[4:], jnp.int32), jnp.array([4], jnp.int32),
                          kv_k, kv_ks, kv_v, kv_vs, *weights)
        np.testing.assert_allclose(np.array(chunked)[-1], np.array(single)[-1], atol=2e-4, rtol=1e-3)


class TestAccuracyOrdering:
    def test_kv_precision_error_ordering(self, params16):
        """Table 1 primitive: logit error vs full precision grows as KV
        precision shrinks, and stays small for kv8 ("accuracy equivalence")."""
        weights = wflat(params16, "w16")
        toks = np.arange(1, 33)  # 32-token prompt

        def decode_after_prefill(kvprec):
            _, kc, kcs, vc, vcs = run_prefill("w16", kvprec, weights, toks)
            kv_k, kv_ks, kv_v, kv_vs = empty_cache(kvprec, 1)
            s = len(toks)
            kv_k = kv_k.at[:, 0, :, :s].set(kc)
            kv_v = kv_v.at[:, 0, :, :s].set(vc)
            kv_ks = kv_ks.at[:, 0, :, :s].set(kcs)
            kv_vs = kv_vs.at[:, 0, :, :s].set(vcs)
            step = jax.jit(M.make_decode_step(SPEC, "w16", kvprec))
            logits, *_ = step(jnp.array([40], jnp.int32), jnp.array([s], jnp.int32),
                              kv_k, kv_ks, kv_v, kv_vs, *weights)
            return np.array(logits[0])

        base = decode_after_prefill("kv16")
        err8 = np.abs(decode_after_prefill("kv8") - base).max()
        err4 = np.abs(decode_after_prefill("kv4") - base).max()
        assert err8 < err4, f"kv8 err {err8} should be < kv4 err {err4}"
        assert err8 < 0.05 * np.abs(base).max(), f"kv8 not equivalent: {err8}"

    def test_w4_perturbs_but_preserves_argmax_mostly(self, params16, params4):
        w16 = wflat(params16, "w16")
        w4 = wflat(params4, "w4")
        toks = np.arange(5, 21)
        l16, *_ = run_prefill("w16", "kv16", w16, toks)
        l4, *_ = run_prefill("w4", "kv16", w4, toks)
        l16, l4 = np.array(l16)[-1], np.array(l4)[-1]
        # Top-5 of the full-precision model should contain the W4 argmax.
        top5 = np.argsort(l16)[-5:]
        assert np.argmax(l4) in top5

"""Layer-1 Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes, precisions, and group sizes; every case asserts
``allclose`` between the kernel (interpret=True) and ``ref.py``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize as Q
from compile.kernels import mp_attention, mp_gemm, ref


def rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).standard_normal(shape) * scale).astype(np.float32)


class TestGemmW4:
    @pytest.mark.parametrize("m,k,n", [(1, 128, 128), (8, 256, 256), (4, 64, 384)])
    def test_matches_ref(self, m, k, n):
        g = 64
        x = rand((m, k), seed=m + n)
        w = rand((k, n), seed=k)
        codes, scales = Q.quantize_groupwise_int4(w, g)
        wp = Q.pack_int4_along_k(codes)
        out = mp_gemm.gemm_w4(jnp.array(x), jnp.array(wp), jnp.array(scales), group_size=g)
        expect = ref.gemm_w4_ref(jnp.array(x), jnp.array(wp), jnp.array(scales), g)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)

    def test_dequant_exactness(self):
        # The kernel's in-kernel dequant must be *bitwise* the reference
        # dequant: identical matmul inputs → identical f32 outputs.
        g, k, n = 32, 64, 128
        w = rand((k, n), seed=9)
        codes, scales = Q.quantize_groupwise_int4(w, g)
        wp = Q.pack_int4_along_k(codes)
        x = np.eye(k, dtype=np.float32)  # identity extracts dequantized W
        out = np.array(mp_gemm.gemm_w4(jnp.array(x), jnp.array(wp), jnp.array(scales), group_size=g))
        expect = Q.dequantize_groupwise(codes, scales)
        np.testing.assert_array_equal(out, expect)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(1, 9),
        kg=st.integers(1, 4),
        nb=st.integers(1, 4),
        group=st.sampled_from([32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, m, kg, nb, group, seed):
        k, n = kg * group, nb * 128
        x = rand((m, k), seed=seed)
        w = rand((k, n), seed=seed + 1)
        codes, scales = Q.quantize_groupwise_int4(w, group)
        wp = Q.pack_int4_along_k(codes)
        out = mp_gemm.gemm_w4(jnp.array(x), jnp.array(wp), jnp.array(scales), group_size=group)
        expect = ref.gemm_w4_ref(jnp.array(x), jnp.array(wp), jnp.array(scales), group)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


class TestGemmW8:
    @pytest.mark.parametrize("m,k,n", [(1, 128, 128), (8, 256, 256)])
    def test_matches_ref(self, m, k, n):
        g = 64
        x = rand((m, k), seed=m)
        w = rand((k, n), seed=k + 1)
        codes, scales = Q.quantize_groupwise_int8(w, g)
        out = mp_gemm.gemm_w8(jnp.array(x), jnp.array(codes), jnp.array(scales), group_size=g)
        expect = ref.gemm_w8_ref(jnp.array(x), jnp.array(codes), jnp.array(scales), g)
        # atol covers f32 accumulation-order differences between the tiled
        # kernel and the monolithic reference matmul (~3e-5 at K=256).
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-4)

    def test_w8_more_accurate_than_w4(self):
        k, n, g = 128, 128, 64
        x = rand((1, k), seed=3)
        w = rand((k, n), seed=4)
        exact = x @ w
        c8, s8 = Q.quantize_groupwise_int8(w, g)
        c4, s4 = Q.quantize_groupwise_int4(w, g)
        out8 = np.array(mp_gemm.gemm_w8(jnp.array(x), jnp.array(c8), jnp.array(s8), group_size=g))
        out4 = np.array(mp_gemm.gemm_w4(jnp.array(x), jnp.array(Q.pack_int4_along_k(c4)),
                                        jnp.array(s4), group_size=g))
        assert np.abs(out8 - exact).mean() < np.abs(out4 - exact).mean()


def _mk_attention_inputs(b, h, hkv, t, d, kv_len_vals, seed=0):
    q = rand((b, h, d), seed=seed)
    k = rand((b, hkv, t, d), seed=seed + 1)
    v = rand((b, hkv, t, d), seed=seed + 2)
    kv_len = np.asarray(kv_len_vals, np.int32)
    return q, k, v, kv_len


class TestAttentionDecode:
    @pytest.mark.parametrize("kvprec", ["kv16", "kv8", "kv4"])
    def test_matches_ref(self, kvprec):
        b, h, hkv, t, d = 2, 8, 4, 128, 32
        q, k, v, kv_len = _mk_attention_inputs(b, h, hkv, t, d, [37, 128], seed=10)
        if kvprec == "kv16":
            out = mp_attention.attention_decode_kv16(
                jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(kv_len))
            expect = ref.attention_decode_ref(jnp.array(q), jnp.array(k), jnp.array(v),
                                              jnp.array(kv_len))
        elif kvprec == "kv8":
            kq, ks = Q.quantize_kv_int8(k)
            vq, vs = Q.quantize_kv_int8(v)
            out = mp_attention.attention_decode_kv8(
                jnp.array(q), jnp.array(kq), jnp.array(ks),
                jnp.array(vq), jnp.array(vs), jnp.array(kv_len))
            expect = ref.attention_decode_ref(
                jnp.array(q), jnp.array(Q.dequantize_kv_int8(kq, ks)),
                jnp.array(Q.dequantize_kv_int8(vq, vs)), jnp.array(kv_len))
        else:
            kq, ks = Q.quantize_kv_int4(k)
            vq, vs = Q.quantize_kv_int4(v)
            out = mp_attention.attention_decode_kv4(
                jnp.array(q), jnp.array(kq), jnp.array(ks),
                jnp.array(vq), jnp.array(vs), jnp.array(kv_len))
            expect = ref.attention_decode_ref(
                jnp.array(q), jnp.array(Q.dequantize_kv_int4(kq, ks)),
                jnp.array(Q.dequantize_kv_int4(vq, vs)), jnp.array(kv_len))
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    def test_mask_respected(self):
        # Changing K/V beyond kv_len must not change the output.
        b, h, hkv, t, d = 1, 4, 2, 128, 16
        q, k, v, kv_len = _mk_attention_inputs(b, h, hkv, t, d, [40], seed=20)
        out1 = np.array(mp_attention.attention_decode_kv16(
            jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(kv_len)))
        k2, v2 = k.copy(), v.copy()
        k2[:, :, 40:] = 999.0
        v2[:, :, 40:] = -999.0
        out2 = np.array(mp_attention.attention_decode_kv16(
            jnp.array(q), jnp.array(k2), jnp.array(v2), jnp.array(kv_len)))
        np.testing.assert_array_equal(out1, out2)

    def test_single_token_history(self):
        # kv_len = 1: softmax over one entry → output == v[0] per head.
        b, h, hkv, t, d = 1, 2, 1, 64, 8
        q, k, v, kv_len = _mk_attention_inputs(b, h, hkv, t, d, [1], seed=30)
        out = np.array(mp_attention.attention_decode_kv16(
            jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(kv_len)))
        for head in range(h):
            np.testing.assert_allclose(out[0, head], v[0, 0, 0], rtol=1e-5, atol=1e-5)

    def test_gqa_head_mapping(self):
        # With q identical across a KV group, outputs within the group match.
        b, h, hkv, t, d = 1, 4, 2, 64, 16
        q, k, v, kv_len = _mk_attention_inputs(b, h, hkv, t, d, [50], seed=40)
        q[0, 1] = q[0, 0]  # heads 0,1 share kv head 0
        q[0, 3] = q[0, 2]  # heads 2,3 share kv head 1
        out = np.array(mp_attention.attention_decode_kv16(
            jnp.array(q), jnp.array(k), jnp.array(v), jnp.array(kv_len)))
        np.testing.assert_allclose(out[0, 0], out[0, 1], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(out[0, 2], out[0, 3], rtol=1e-5, atol=1e-6)

    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        group=st.sampled_from([1, 2, 4]),
        hkv=st.sampled_from([1, 2, 4]),
        tiles=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
        data=st.data(),
    )
    def test_hypothesis_kv8(self, b, group, hkv, tiles, d, seed, data):
        h = group * hkv
        t = tiles * mp_attention.KV_TILE
        kv_len = [data.draw(st.integers(1, t)) for _ in range(b)]
        q, k, v, kv_len = _mk_attention_inputs(b, h, hkv, t, d, kv_len, seed=seed)
        kq, ks = Q.quantize_kv_int8(k)
        vq, vs = Q.quantize_kv_int8(v)
        out = mp_attention.attention_decode_kv8(
            jnp.array(q), jnp.array(kq), jnp.array(ks),
            jnp.array(vq), jnp.array(vs), jnp.array(kv_len))
        expect = ref.attention_decode_ref(
            jnp.array(q), jnp.array(Q.dequantize_kv_int8(kq, ks)),
            jnp.array(Q.dequantize_kv_int8(vq, vs)), jnp.array(kv_len))
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

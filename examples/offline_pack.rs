//! §4.1 hardware-aware weight packing, end to end on a real weight matrix:
//! quantize → pack through the emulated warp pipeline → verify the three
//! layout guarantees with the access analyzer → round-trip bit-exactly.
//!
//!     cargo run --release --example offline_pack

use turbomind::quant::access::analyze_global;
use turbomind::quant::packing::{naive_fragment_access, PERMUTE};
use turbomind::quant::{pack_weights_hw_aware, GroupwiseQuant, QuantizedMatrix};
use turbomind::util::rng::Rng;

fn main() {
    let (k, n) = (512usize, 2048usize);
    let mut rng = Rng::new(2024);
    let w: Vec<f32> = (0..k * n).map(|_| (rng.next_f32() - 0.5) * 2.0).collect();

    println!("step 0  quantize [{k} x {n}] f32 → groupwise INT4 (group 64)");
    let q = QuantizedMatrix::quantize(&w, k, n, GroupwiseQuant::int4(64));
    println!("        codes {} B + scales {} B (vs {} B f32)",
             q.codes.len(), q.scales.len() * 4, w.len() * 4);

    println!("step i-iv  §4.1 pipeline: bit-extend → ldmatrix fragments → compress+permute {PERMUTE:?} → two-fragment store");
    let p = pack_weights_hw_aware(&q);
    println!("        {} tiles packed into {} u32 words", p.n_tiles(), p.words.len());

    // Guarantee 1+2: every runtime tile-pair load is coalesced, conflict-free.
    let mut worst_tx = 0;
    let mut worst_conflict = 0;
    for t in 0..p.n_tiles() {
        let r = p.runtime_load_report(t, 128);
        worst_tx = worst_tx.max(r.transactions);
        worst_conflict = worst_conflict.max(r.bank_conflict_degree);
        assert!(r.is_fully_coalesced() && r.is_conflict_free(), "tile {t}");
    }
    println!("verify  packed loads : worst case {worst_tx} transactions / 256B pair, conflict degree {worst_conflict}");

    let naive = analyze_global(&naive_fragment_access(n, 0, 0), 128);
    println!("        naive loads  : {} transactions / 128B tile, conflict degree {}",
             naive.transactions, naive.bank_conflict_degree);

    // Guarantee 3: fragments land in MMA register order — so unpacking via
    // the runtime I2F path reproduces the source codes exactly.
    let codes = p.unpack_codes();
    for r in 0..k {
        for c in 0..n {
            assert_eq!(codes[r * n + c], q.code_at(r, c));
        }
    }
    println!("verify  round-trip   : all {} codes exact after pack → I2F-extract", k * n);

    let dq = p.dequantize();
    let err: f32 = dq
        .iter()
        .zip(&w)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    println!("        max |deq - w|: {err:.5} (bounded by half an LSB per group: {:.5})",
             q.error_bound());
}

//! Quickstart: load the AOT artifacts, serve a handful of requests through
//! the mixed-precision engine, and print tokens + serving metrics.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This exercises the whole stack: Rust coordinator → paged quantized KV
//! pool → PJRT-compiled JAX graphs → Pallas mixed-precision kernels.

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, Request};
use turbomind::metrics::MetricsCollector;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("TM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let cfg = EngineConfig {
        artifacts_dir: artifacts,
        precision: "W4A16KV8".parse().unwrap(),
        max_batch: 4,
        kv_pool_tokens: 16 * 512,
        ..EngineConfig::default()
    };
    println!("loading engine ({} …)", cfg.precision);
    let mut engine = Engine::new(cfg)?;
    engine.warmup()?;
    let m = engine.model().clone();
    println!(
        "model {}: {} layers, d_model {}, vocab {}",
        m.name, m.n_layers, m.d_model, m.vocab_size
    );

    // Eight deterministic prompts (token ids; tokenization is out of scope).
    let prompts: Vec<Vec<i32>> = (0..8)
        .map(|i| (0..12 + i * 5).map(|j| ((i * 131 + j * 17 + 3) % 2048) as i32).collect())
        .collect();
    let t0 = std::time::Instant::now();
    for p in &prompts {
        engine.submit(Request::new(p.clone(), 16))?;
    }
    let outputs = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut metrics = MetricsCollector::new();
    for o in &outputs {
        println!(
            "req {:>2}  prompt {:>3} tok  ttft {:>6.3}s  latency {:>6.3}s  → {:?}",
            o.id, o.prompt_len, o.ttft, o.latency,
            &o.tokens[..o.tokens.len().min(8)]
        );
        metrics.record(o.latency, o.ttft, o.latency, o.prompt_len, o.tokens.len());
    }
    let lat = metrics.latency_percentiles().unwrap();
    let (ptoks, gtoks) = metrics.total_tokens();
    println!("\n{} requests in {wall:.2}s", outputs.len());
    println!("latency p50 {:.3}s  p90 {:.3}s  p99 {:.3}s", lat.p50, lat.p90, lat.p99);
    println!(
        "prompt tokens {ptoks}, generated {gtoks} ({:.1} tok/s end-to-end)",
        gtoks as f64 / wall
    );
    println!(
        "engine: {} prefill iters, {} decode iters, {} padded slots",
        engine.stats.prefill_iters, engine.stats.decode_iters, engine.stats.padded_slots
    );
    Ok(())
}

//! Networked serving demo: start the JSON-lines TCP server on a background
//! engine and drive it with concurrent clients — the deployment shape a
//! downstream user would run (`turbomind serve` wraps the same path).
//!
//!     cargo run --release --example tcp_server

use std::thread;

use turbomind::config::EngineConfig;
use turbomind::coordinator::Engine;
use turbomind::server::{serve, Client};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("TM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let addr = "127.0.0.1:7181";
    let n_clients = 3usize;
    let per_client = 2usize;

    let cfg = EngineConfig {
        artifacts_dir: artifacts,
        precision: "W4A16KV8".parse().unwrap(),
        max_batch: 4,
        kv_pool_tokens: 16 * 512,
        ..EngineConfig::default()
    };
    let engine = Engine::new(cfg)?;
    engine.warmup()?;

    // Client threads (the engine must own the main thread: PJRT handles are
    // not Send).
    let mut handles = vec![];
    for c in 0..n_clients {
        handles.push(thread::spawn(move || -> anyhow::Result<()> {
            // Wait for the listener.
            let mut client = loop {
                match Client::connect(addr) {
                    Ok(cl) => break cl,
                    Err(_) => thread::sleep(std::time::Duration::from_millis(50)),
                }
            };
            for r in 0..per_client {
                let prompt: Vec<i32> =
                    (0..16).map(|j| ((c * 997 + r * 131 + j * 7) % 2048) as i32).collect();
                let resp = client.generate(&prompt, 8)?;
                println!(
                    "client {c} req {r}: finish={} tokens={}",
                    resp.req_str("finish").unwrap_or("?"),
                    resp.req_arr("tokens").map(|t| t.len()).unwrap_or(0),
                );
            }
            Ok(())
        }));
    }

    // Serve exactly the expected number of requests, then return.
    serve(engine, addr, Some(n_clients * per_client))?;
    for h in handles {
        h.join().expect("client thread")?;
    }
    println!("tcp_server demo complete");
    Ok(())
}

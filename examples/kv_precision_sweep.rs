//! KV-precision sensitivity on the **real** engine (the Fig 21 analogue on
//! this testbed): serve the same workload at KV16 / KV8 / KV4 and compare
//! throughput and KV-pool footprint.
//!
//!     cargo run --release --example kv_precision_sweep

use std::time::Instant;

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, Request};
use turbomind::util::rng::Rng;

fn run(precision: &str, artifacts: &str) -> anyhow::Result<(f64, usize, usize)> {
    let cfg = EngineConfig {
        artifacts_dir: artifacts.to_string(),
        precision: precision.parse().map_err(|e| anyhow::anyhow!("{e}"))?,
        max_batch: 4,
        kv_pool_tokens: 16 * 512,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg)?;
    engine.warmup()?;
    let mut rng = Rng::new(9);
    for i in 0..8 {
        let prompt: Vec<i32> = (0..24 + 8 * i).map(|_| rng.below(2048) as i32).collect();
        engine.submit(Request::new(prompt, 24))?;
    }
    let t0 = Instant::now();
    let outs = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    let gen: usize = outs.iter().map(|o| o.tokens.len()).sum();
    // Bytes one full pool would occupy at this precision.
    let pool = engine.kv_pool();
    let pool_bytes = pool.total_blocks() * pool.block_tokens() * pool.token_code_bytes();
    Ok((gen as f64 / wall, gen, pool_bytes))
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("TM_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("KV-precision sweep on the real engine (W4 weights, 8 requests x 24 tokens)\n");
    println!("{:<12} {:>12} {:>10} {:>16}", "precision", "tok/s", "tokens", "kv pool bytes");
    let mut first = 0.0;
    for (i, prec) in ["W4A16KV16", "W4A16KV8", "W4A16KV4"].iter().enumerate() {
        let (thr, gen, pool_bytes) = run(prec, &artifacts)?;
        if i == 0 {
            first = thr;
        }
        println!(
            "{:<12} {:>12.1} {:>10} {:>16} ({:+.1}% vs KV16)",
            prec, thr, gen, pool_bytes,
            (thr / first - 1.0) * 100.0
        );
    }
    println!("\npaper Fig 21: KV8 avg +11.9%, KV4 avg +18.3% at scale;");
    println!("on CPU-PJRT the win shows up as the 2-4x smaller pool footprint —");
    println!("the same pool budget holds 2-4x more concurrent sequences.");
    Ok(())
}

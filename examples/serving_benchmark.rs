//! End-to-end serving benchmark on the **real** engine: a Poisson arrival
//! trace with ShareGPT-shaped lengths (scaled to the tiny model's context),
//! reporting throughput, TTFT, and latency percentiles — the paper's §5.1
//! metrics measured on this testbed. This is the repository's headline
//! end-to-end validation run (EXPERIMENTS.md).
//!
//!     cargo run --release --example serving_benchmark -- \
//!         --rate 2.0 --requests 24 --precision W4A16KV8

use std::time::Instant;

use turbomind::config::EngineConfig;
use turbomind::coordinator::{Engine, Request};
use turbomind::metrics::MetricsCollector;
use turbomind::util::args::Args;
use turbomind::workload::{WorkloadGen, WorkloadKind};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let rate = args.get_f64("rate", 2.0);
    let n = args.get_usize("requests", 24);
    let precision = args.get_or("precision", "W4A16KV8").to_string();
    let artifacts = args.get_or("artifacts", "artifacts").to_string();

    let cfg = EngineConfig {
        artifacts_dir: artifacts,
        precision: precision.parse().map_err(|e| anyhow::anyhow!("{e}"))?,
        max_batch: 8,
        kv_pool_tokens: 16 * 1024,
        ..EngineConfig::default()
    };
    let mut engine = Engine::new(cfg)?;
    engine.warmup()?;
    let vocab = engine.model().vocab_size;

    // ShareGPT-shaped lengths scaled into the tiny model's 512 context.
    let gen = WorkloadGen::new(WorkloadKind::Chat, rate, 42);
    let trace = gen.generate_scaled(n, 128, 48);

    println!("serving {n} requests at {rate} req/s, precision {precision}");
    let t0 = Instant::now();
    let mut submitted = 0usize;
    let mut metrics = MetricsCollector::new();
    let mut done = 0usize;
    while done < n {
        // Submit every request whose arrival time has passed (open-loop).
        let now = t0.elapsed().as_secs_f64();
        while submitted < n && trace[submitted].arrival_s <= now {
            let r = &trace[submitted];
            let prompt = gen.prompt_tokens(submitted, r.prompt_tokens, vocab);
            engine.submit(Request::new(prompt, r.gen_tokens))?;
            submitted += 1;
        }
        if engine.has_work() {
            engine.step()?;
        } else if submitted < n {
            // Idle until the next arrival.
            let wait = trace[submitted].arrival_s - t0.elapsed().as_secs_f64();
            if wait > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(0.05)));
            }
        }
        for o in engine.take_outputs() {
            let now = t0.elapsed().as_secs_f64();
            metrics.record(o.latency, o.ttft, now, o.prompt_len, o.tokens.len());
            done += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let lat = metrics.latency_percentiles().unwrap();
    let ttft = metrics.ttft_percentiles().unwrap();
    let (ptoks, gtoks) = metrics.total_tokens();
    println!("\n== serving results ({precision}) ==");
    println!("wall time          : {wall:.2}s");
    println!("request throughput : {:.3} req/s", n as f64 / wall);
    println!("token throughput   : {:.1} tok/s generated ({ptoks} prompt, {gtoks} gen)",
             gtoks as f64 / wall);
    println!("TTFT    p50 {:>7.3}s  p90 {:>7.3}s  p99 {:>7.3}s", ttft.p50, ttft.p90, ttft.p99);
    println!("latency p50 {:>7.3}s  p90 {:>7.3}s  p99 {:>7.3}s", lat.p50, lat.p90, lat.p99);
    println!(
        "engine stats: {} prefill iters, {} decode iters, {} aborted",
        engine.stats.prefill_iters, engine.stats.decode_iters, engine.stats.aborted
    );
    Ok(())
}
